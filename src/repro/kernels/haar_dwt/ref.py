"""Pure-jnp oracle for the haar_dwt kernel (delegates to repro.core.haar)."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import haar


def haar_dwt_fwd(g: jax.Array, level: int) -> Tuple[jax.Array, ...]:
    a, details = haar.haar_forward(g, level)
    return (a.astype(g.dtype), *(d.astype(g.dtype) for d in details))


def haar_dwt_fwd_q(g: jax.Array, level: int, detail_dtype
                   ) -> Tuple[jax.Array, ...]:
    """Oracle for the fused quantize+pack forward: f32 transform, f32
    approximation, detail bands narrowed to ``detail_dtype``."""
    a, details = haar.haar_forward(g.astype(jnp.float32), level)
    return (a, *(d.astype(detail_dtype) for d in details))


def haar_dwt_inv(a: jax.Array, details: Sequence[jax.Array]) -> jax.Array:
    return haar.haar_inverse(a, list(details)).astype(a.dtype)
