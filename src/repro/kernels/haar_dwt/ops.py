"""jit'd public wrapper for the haar_dwt kernel with backend dispatch.

On TPU the Pallas kernel runs natively; elsewhere (CPU container) we use
``interpret=True`` for validation or fall back to the jnp oracle.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax

from repro.kernels.haar_dwt import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("level", "impl"))
def dwt(g: jax.Array, level: int, impl: str = "auto") -> Tuple[jax.Array, ...]:
    """Forward multi-level DWT. ``impl``: auto|pallas|interpret|jnp."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "pallas":
        return kernel.haar_dwt_fwd(g, level)
    if impl == "interpret":
        return kernel.haar_dwt_fwd(g, level, interpret=True)
    return ref.haar_dwt_fwd(g, level)


@functools.partial(jax.jit, static_argnames=("impl",))
def idwt(a: jax.Array, details: Sequence[jax.Array], impl: str = "auto") -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "pallas":
        return kernel.haar_dwt_inv(a, details)
    if impl == "interpret":
        return kernel.haar_dwt_inv(a, details, interpret=True)
    return ref.haar_dwt_inv(a, details)
