"""jit'd public wrapper for the haar_dwt kernel with backend dispatch.

Backend selection ('auto') routes through repro.compat — native Pallas on
TPU, the jnp oracle elsewhere — and launchers pass an explicit impl from
MeshContext.kernel_impl so benchmarks can sweep backends.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels.haar_dwt import kernel, ref


def dwt(g: jax.Array, level: int, impl: str = "auto") -> Tuple[jax.Array, ...]:
    """Forward multi-level DWT. ``impl``: auto|pallas|interpret|jnp.

    'auto' resolves OUTSIDE the jitted body — as a static jit arg it would
    freeze the REPRO_KERNEL_IMPL env read into the trace cache."""
    return _dwt(g, level, compat.resolve_kernel_impl(impl))


@functools.partial(jax.jit, static_argnames=("level", "impl"))
def _dwt(g, level, impl):
    if impl == "pallas":
        return kernel.haar_dwt_fwd(g, level)
    if impl == "interpret":
        return kernel.haar_dwt_fwd(g, level, interpret=True)
    return ref.haar_dwt_fwd(g, level)


def dwt_wire(g: jax.Array, level: int, detail_dtype,
             impl: str = "auto") -> Tuple[jax.Array, ...]:
    """Fused wire forward for ``distributed.compression.reduce_terms``:
    one launch emits ``(A_l f32, D_l..D_1 detail_dtype)`` — the detail
    quantize happens at the tile write instead of a second HBM pass."""
    return _dwt_wire(g, level, jnp.dtype(detail_dtype),
                     compat.resolve_kernel_impl(impl))


@functools.partial(jax.jit, static_argnames=("level", "detail_dtype", "impl"))
def _dwt_wire(g, level, detail_dtype, impl):
    if impl == "pallas":
        return kernel.haar_dwt_fwd_q(g, level, detail_dtype)
    if impl == "interpret":
        return kernel.haar_dwt_fwd_q(g, level, detail_dtype, interpret=True)
    return ref.haar_dwt_fwd_q(g, level, detail_dtype)


def idwt(a: jax.Array, details: Sequence[jax.Array],
         impl: str = "auto") -> jax.Array:
    return _idwt(a, details, compat.resolve_kernel_impl(impl))


@functools.partial(jax.jit, static_argnames=("impl",))
def _idwt(a, details, impl):
    if impl == "pallas":
        return kernel.haar_dwt_inv(a, details)
    if impl == "interpret":
        return kernel.haar_dwt_inv(a, details, interpret=True)
    return ref.haar_dwt_inv(a, details)
