"""Pallas TPU kernel: blocked multi-level Haar DWT (forward & inverse).

TPU adaptation (vs the paper's conv-based ptwt on GPU): the level-k Haar
coefficient ``j`` depends only on input columns ``[j·2^k, (j+1)·2^k)`` —
the transform is *block-local*.  A ``(bm, bn)`` VMEM tile whose width is a
multiple of ``2^l`` is therefore fully self-contained: one HBM read of the
gradient tile produces every band with no cross-tile communication.  All
levels run while the tile is VMEM-resident (HBM traffic = 1× read + 1×
write, vs ``l`` passes for a level-at-a-time implementation).

Grid: ``(m/bm, n/bn)``.  Outputs are one array per band —
``A_l: (m, n/2^l)``, ``D_k: (m, n/2^k)`` — each with its own BlockSpec, so
the global band layout falls out of the index maps (no strided HBM writes).

Butterfly inside the kernel uses minor-dim reshapes (``(bm, w/2, 2)``),
which Mosaic lowers to lane shuffles; matmul units are not involved (the op
is bandwidth-bound by design).
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INV_SQRT2 = 0.7071067811865476


def _fwd_body(level: int, g_ref, *out_refs):
    x = g_ref[...].astype(jnp.float32)
    bm, bn = x.shape
    a = x
    details: List[jax.Array] = []
    for _ in range(level):
        pairs = a.reshape(bm, a.shape[-1] // 2, 2)
        even, odd = pairs[..., 0], pairs[..., 1]
        a = (even + odd) * INV_SQRT2
        details.append((even - odd) * INV_SQRT2)
    details.reverse()  # [D_l, ..., D_1]
    out_refs[0][...] = a.astype(out_refs[0].dtype)
    for ref, d in zip(out_refs[1:], details):
        ref[...] = d.astype(ref.dtype)


def _inv_body(level: int, a_ref, *rest):
    d_refs, out_ref = rest[:-1], rest[-1]
    x = a_ref[...].astype(jnp.float32)
    bm = x.shape[0]
    for d_ref in d_refs:  # D_l first
        d = d_ref[...].astype(jnp.float32)
        even = (x + d) * INV_SQRT2
        odd = (x - d) * INV_SQRT2
        x = jnp.stack([even, odd], axis=-1).reshape(bm, x.shape[-1] * 2)
    out_ref[...] = x.astype(out_ref.dtype)


def _pick_blocks(m: int, n: int, level: int) -> Tuple[int, int]:
    """Largest hardware-friendly tile that keeps the working set in VMEM.

    bn must be a multiple of ``2^l`` (self-containment) and ideally of 128
    (lane width); bm a multiple of 8 (sublanes).  Working set ≈ 3·bm·bn·4B
    (input + bands + inverse temp) — cap at ~4 MB of the ~16 MB VMEM.
    """
    unit = max(1 << level, 128)
    bn = unit
    while bn * 2 <= min(n, 2048) and n % (bn * 2) == 0:
        bn *= 2
    if n % bn != 0:  # n not a multiple of the unit: fall back to full width
        bn = n
    bm = 8
    while bm * 2 <= min(m, 1024) and m % (bm * 2) == 0 and 3 * (bm * 2) * bn * 4 <= 4 * 1024 * 1024:
        bm *= 2
    if m % bm != 0:
        bm = m
    return bm, bn


def haar_dwt_fwd(g: jax.Array, level: int, *, interpret: bool = False
                 ) -> Tuple[jax.Array, ...]:
    """Returns ``(A_l, D_l, ..., D_1)``; 2-D input ``(m, n)``."""
    m, n = g.shape
    if n % (1 << level) != 0:
        raise ValueError(f"n={n} not divisible by 2^{level}")
    bm, bn = _pick_blocks(m, n, level)
    grid = (m // bm, n // bn)
    widths = [n >> level] + [n >> k for k in range(level, 0, -1)]
    bwidths = [bn >> level] + [bn >> k for k in range(level, 0, -1)]
    out_shape = [jax.ShapeDtypeStruct((m, w), g.dtype) for w in widths]
    out_specs = [pl.BlockSpec((bm, bw), lambda i, j: (i, j)) for bw in bwidths]
    return pl.pallas_call(
        functools.partial(_fwd_body, level),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(g)


def haar_dwt_fwd_q(g: jax.Array, level: int, detail_dtype, *,
                   interpret: bool = False) -> Tuple[jax.Array, ...]:
    """Fused DWT + wire quantize: ``(A_l f32, D_l..D_1 detail_dtype)``.

    The wire path's ``reduce_terms`` splits the gradient and narrows the
    detail bands for the all-reduce.  Staged, that materializes every band
    in f32 before a second pass re-reads and narrows them; here the cast
    happens in-register at the tile write (``_fwd_body`` already casts each
    band to its out-ref dtype), so the f32 detail intermediates never touch
    HBM — one launch emits the exact wire payload."""
    m, n = g.shape
    if n % (1 << level) != 0:
        raise ValueError(f"n={n} not divisible by 2^{level}")
    bm, bn = _pick_blocks(m, n, level)
    grid = (m // bm, n // bn)
    widths = [n >> level] + [n >> k for k in range(level, 0, -1)]
    bwidths = [bn >> level] + [bn >> k for k in range(level, 0, -1)]
    dtypes = [jnp.float32] + [detail_dtype] * level
    out_shape = [jax.ShapeDtypeStruct((m, w), d)
                 for w, d in zip(widths, dtypes)]
    out_specs = [pl.BlockSpec((bm, bw), lambda i, j: (i, j)) for bw in bwidths]
    return pl.pallas_call(
        functools.partial(_fwd_body, level),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(g)


def haar_dwt_inv(a: jax.Array, details: Sequence[jax.Array], *,
                 interpret: bool = False) -> jax.Array:
    """Inverse: ``(A_l, [D_l..D_1]) -> (m, n)``."""
    level = len(details)
    m, na = a.shape
    n = na << level
    bm, bn = _pick_blocks(m, n, level)
    grid = (m // bm, n // bn)
    bwidths = [bn >> level] + [bn >> k for k in range(level, 0, -1)]
    in_specs = [pl.BlockSpec((bm, bw), lambda i, j: (i, j)) for bw in bwidths]
    return pl.pallas_call(
        functools.partial(_inv_body, level),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, *details)
