"""jit'd wrapper for the fused GWT-Adam kernel, with backend dispatch and
leading-batch handling: any leading dims — stacked ``(L, m, n)`` scan
parameters *and* the optimizer engine's shape buckets — are flattened and
vmapped, so one call serves a whole bucket (one launch per bucket, not per
leaf).

``fused_update`` is the entry point used by ``repro.core.gwt`` when
``impl='pallas'`` (the GWT rules' ``vector_update``: the engine hands it
the full ``(L, m, n)`` stack in a single call).  Semantics match
``repro.core.gwt._gwt_core`` exactly (tested leaf-by-leaf); the
norm-growth limiter stays in the caller (vmapped per leaf).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels.gwt_adam import kernel, ref


def _tile_fn(impl: str, level: int, b1: float, b2: float, eps: float):
    impl = compat.resolve_kernel_impl(impl)
    if impl == "pallas":
        return functools.partial(kernel.gwt_adam_tile, level=level, b1=b1,
                                 b2=b2, eps=eps)
    if impl == "interpret":
        return functools.partial(kernel.gwt_adam_tile, level=level, b1=b1,
                                 b2=b2, eps=eps, interpret=True)
    return functools.partial(ref.gwt_adam_tile, level=level, b1=b1, b2=b2,
                             eps=eps)


def fused_update(g: jax.Array, state: dict, step: jax.Array, *,
                 level: int, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-6, impl: str = "auto"
                 ) -> Tuple[jax.Array, jax.Array, dict]:
    """Returns ``(g_tilde, lr_mult, new_state)`` — drop-in for the jnp core.

    ``impl``: auto|pallas|interpret|jnp — 'auto' resolves per platform via
    repro.compat (launchers pass MeshContext.kernel_impl explicitly).
    Resolution happens OUTSIDE the jitted body: 'auto' as a static jit arg
    would freeze the REPRO_KERNEL_IMPL env read into the trace cache."""
    impl = compat.resolve_kernel_impl(impl)
    return _fused_update(g, state, step, level=level, b1=b1, b2=b2, eps=eps,
                         impl=impl)


@functools.partial(jax.jit, static_argnames=("level", "b1", "b2", "eps", "impl"))
def _fused_update(g, state, step, *, level, b1, b2, eps, impl):
    fn = _tile_fn(impl, level, b1, b2, eps)
    if g.ndim > 2:  # stacked scan leaves (L, m, n)
        lead = g.shape[:-2]
        g2 = g.reshape((-1,) + g.shape[-2:])
        m2 = state["m"].reshape((-1,) + state["m"].shape[-2:])
        v2 = state["v"].reshape((-1,) + state["v"].shape[-2:])
        gt, m, v, _ = jax.vmap(fn)(g2, m2, v2)
        gt = gt.reshape(lead + gt.shape[-2:])
        m = m.reshape(lead + m.shape[-2:])
        v = v.reshape(lead + v.shape[-2:])
    else:
        gt, m, v, _ = fn(g, state["m"], state["v"])
    t = step.astype(jnp.float32) + 1.0
    lr_mult = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)
    return gt, lr_mult, {"m": m, "v": v}


# ---------------------------------------------------------------------------
# q8 path: blocked-int8 moments (state codec 'int8'), requant fused in.
# ---------------------------------------------------------------------------

def _tile_fn_q8(impl: str, shape, level: int, block: int,
                b1: float, b2: float, eps: float):
    """Per-(impl, leaf-shape) q8 tile function.  The Pallas path needs
    block-aligned row tiles (``kernel.q8_row_block``); shapes it cannot
    tile fall back to the jnp oracle — a static, per-bucket decision."""
    if impl in ("pallas", "interpret") and \
            kernel.q8_row_block(shape[-2], shape[-1], level, block) is not None:
        return functools.partial(kernel.gwt_adam_tile_q8, level=level,
                                 block=block, b1=b1, b2=b2, eps=eps,
                                 interpret=impl == "interpret")
    return functools.partial(ref.gwt_adam_tile_q8, level=level, block=block,
                             b1=b1, b2=b2, eps=eps)


def fused_update_q8(g: jax.Array, state: dict, step: jax.Array,
                    key: jax.Array, leaf_ids: jax.Array, *,
                    level: int, block: int = 64, b1: float = 0.9,
                    b2: float = 0.999, eps: float = 1e-6,
                    impl: str = "auto") -> Tuple[jax.Array, jax.Array, dict]:
    """``fused_update`` over blocked-int8 moments: ``state`` is the encoded
    layout ``{"m": {"q", "scale"}, "v": {"q", "scale"}}``; dequant → update
    → stochastic requant happens inside the tile (Pallas epilogue or jnp
    oracle).  ``key`` is ``opt_state["codec_key"]``; ``leaf_ids`` the
    bucket's flatten-order leaf indices (scalar for a single leaf) — the
    per-slot salts (m=0, v=1) match ``codec.map_slots`` order, so this
    path rounds identically to the engine's generic scan wrap."""
    impl = compat.resolve_kernel_impl(impl)
    return _fused_update_q8(g, state["m"]["q"], state["m"]["scale"],
                            state["v"]["q"], state["v"]["scale"],
                            step, key, leaf_ids, level=level, block=block,
                            b1=b1, b2=b2, eps=eps, impl=impl)


@functools.partial(jax.jit, static_argnames=("level", "block", "b1", "b2",
                                             "eps", "impl"))
def _fused_update_q8(g, qm, sm, qv, sv, step, key, leaf_ids, *,
                     level, block, b1, b2, eps, impl):
    from repro.optim import codec as codec_lib
    salt_m = codec_lib.slot_salt(key, step, 0, leaf_ids)
    salt_v = codec_lib.slot_salt(key, step, 1, leaf_ids)
    if g.ndim > 2:  # stacked scan leaves (L, *extra, m, n)
        # The codec blocks/salts over each leaf's row-major FLAT order, so
        # a 3-D+ leaf's extra dims can't become vmap axes (scales and
        # rounding indices span them).  Merging them into the row axis
        # keeps the flat order bit-identical and the DHT is per-row, so
        # the tile math is unchanged; vmap only over the leaf axis L.
        row = lambda a: a.reshape(a.shape[0], -1, a.shape[-1])
        g2 = row(g)
        fn = _tile_fn_q8(impl, g2.shape, level, block, b1, b2, eps)
        gt, qm2, sm2, qv2, sv2, _ = jax.vmap(fn)(
            g2, row(qm), sm, row(qv), sv,
            salt_m.reshape(-1), salt_v.reshape(-1))
        gt = gt.reshape(g.shape)
        qm2, qv2 = qm2.reshape(qm.shape), qv2.reshape(qv.shape)
    else:
        fn = _tile_fn_q8(impl, g.shape, level, block, b1, b2, eps)
        gt, qm2, sm2, qv2, sv2, _ = fn(g, qm, sm, qv, sv, salt_m, salt_v)
    t = step.astype(jnp.float32) + 1.0
    lr_mult = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)
    return gt, lr_mult, {"m": {"q": qm2, "scale": sm2},
                         "v": {"q": qv2, "scale": sv2}}


# ---------------------------------------------------------------------------
# Fused-write (megakernel) path: limiter + bias-corrected apply + weight
# decay + parameter write move INTO the launch — one kernel call per bucket
# consumes (g, p, m, v, prev_norm) and emits (new_p, new_m, new_v,
# new_norm); g̃ never round-trips HBM.
# ---------------------------------------------------------------------------

def _step_scalars(step, lr_t, alpha, weight_decay, b1, b2):
    """Bias-corrected step size and weight-decay coefficient, computed
    outside the kernel exactly as ``core.gwt._apply`` does (term order
    matters for bitwise parity with the staged path)."""
    t = step.astype(jnp.float32) + 1.0
    lr_mult = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)
    step_size = (lr_t * lr_mult * alpha).astype(jnp.float32)
    wd_coef = jnp.asarray(lr_t * weight_decay, jnp.float32)
    return step_size, wd_coef


def _norm_shapes(g):
    """Normalize a leaf stack to ``(L, rows, n)``: 2-D single leaves gain a
    unit leaf axis; 3-D+ leaves merge extra dims into the row axis (the
    transform is per-row and the limiter norm per-leaf, so row-merging is
    exact — and for q8 it preserves the codec's row-major flat order)."""
    lead2 = g.ndim == 2
    if lead2:
        g = g[None]
    shape = g.shape
    if g.ndim > 3:
        g = g.reshape(g.shape[0], -1, g.shape[-1])
    return g, shape, lead2


def fused_write_update(g: jax.Array, p: jax.Array, state: dict,
                       step: jax.Array, prev_norm: jax.Array, *,
                       lr_t, alpha: float, weight_decay: float,
                       gamma: float, use_limiter: bool, level: int,
                       b1: float = 0.9, b2: float = 0.999,
                       eps: float = 1e-6, impl: str = "auto"):
    """One launch per bucket: DWT→Adam→inverse→limit→param-write.

    Returns ``(new_p, new_norm, new_state)``.  ``impl='jnp'`` routes to the
    tiled ``ref.gwt_adam_fused`` oracle with the SAME row-block choice as
    the kernel, so interpret/pallas bitwise-match it."""
    impl = compat.resolve_kernel_impl(impl)
    return _fused_write_update(
        g, p, state["m"], state["v"], prev_norm, step, lr_t,
        alpha=alpha, weight_decay=weight_decay, gamma=gamma,
        use_limiter=use_limiter, level=level, b1=b1, b2=b2, eps=eps,
        impl=impl)


@functools.partial(jax.jit, static_argnames=(
    "alpha", "weight_decay", "gamma", "use_limiter", "level",
    "b1", "b2", "eps", "impl"))
def _fused_write_update(g, p, m_st, v_st, prev_norm, step, lr_t, *,
                        alpha, weight_decay, gamma, use_limiter, level,
                        b1, b2, eps, impl):
    from repro.kernels.gwt_adam import kernel, ref  # noqa: F811 — local
    step_size, wd_coef = _step_scalars(step, lr_t, alpha, weight_decay,
                                       b1, b2)
    g3, gshape, lead2 = _norm_shapes(g)
    p3, _, _ = _norm_shapes(p)
    m3, _, _ = _norm_shapes(m_st)
    v3, _, _ = _norm_shapes(v_st)
    pn = prev_norm.reshape(g3.shape[0])
    L, mm, nn = g3.shape
    kw = dict(level=level, gamma=gamma, use_limiter=use_limiter,
              weight_decay=weight_decay != 0, b1=b1, b2=b2, eps=eps)
    if impl in ("pallas", "interpret"):
        new_p, m, v, new_norm = kernel.gwt_adam_tile_fused(
            g3, p3, m3, v3, pn, step_size, wd_coef,
            interpret=impl == "interpret", **kw)
    else:
        new_p, m, v, new_norm = ref.gwt_adam_fused(
            g3, p3, m3, v3, pn, step_size, wd_coef,
            bm=kernel.fused_row_block(mm, nn, level), **kw)
    new_p = new_p.reshape(gshape)
    mshape = gshape[:-1] + (nn >> level,)
    m, v = m.reshape(mshape), v.reshape(mshape)
    if lead2:
        new_p, m, v = new_p[0], m[0], v[0]
        new_norm = new_norm.reshape(())
    return new_p, new_norm, {"m": m, "v": v}


def fused_write_update_q8(g: jax.Array, p: jax.Array, state: dict,
                          step: jax.Array, key: jax.Array,
                          leaf_ids: jax.Array, prev_norm: jax.Array, *,
                          lr_t, alpha: float, weight_decay: float,
                          gamma: float, use_limiter: bool, level: int,
                          block: int = 64, b1: float = 0.9,
                          b2: float = 0.999, eps: float = 1e-6,
                          impl: str = "auto"):
    """``fused_write_update`` over blocked-int8 moments: dequant → update →
    stochastic requant AND limit+apply+write all inside the launch.  Shapes
    the q8 kernel cannot tile block-aligned fall back to the jnp oracle —
    a static, per-bucket decision.  Returns ``(new_p, new_norm,
    new_state)`` in the encoded layout."""
    impl = compat.resolve_kernel_impl(impl)
    return _fused_write_update_q8(
        g, p, state["m"]["q"], state["m"]["scale"],
        state["v"]["q"], state["v"]["scale"], prev_norm, step, key,
        leaf_ids, lr_t, alpha=alpha, weight_decay=weight_decay,
        gamma=gamma, use_limiter=use_limiter, level=level, block=block,
        b1=b1, b2=b2, eps=eps, impl=impl)


@functools.partial(jax.jit, static_argnames=(
    "alpha", "weight_decay", "gamma", "use_limiter", "level", "block",
    "b1", "b2", "eps", "impl"))
def _fused_write_update_q8(g, p, qm, sm, qv, sv, prev_norm, step, key,
                           leaf_ids, lr_t, *, alpha, weight_decay, gamma,
                           use_limiter, level, block, b1, b2, eps, impl):
    from repro.kernels.gwt_adam import kernel, ref  # noqa: F811 — local
    from repro.optim import codec as codec_lib
    step_size, wd_coef = _step_scalars(step, lr_t, alpha, weight_decay,
                                       b1, b2)
    g3, gshape, lead2 = _norm_shapes(g)
    p3, _, _ = _norm_shapes(p)
    qm3, _, _ = _norm_shapes(qm)
    qv3, _, _ = _norm_shapes(qv)
    L, mm, nn = g3.shape
    sm2, sv2 = sm.reshape(L, -1), sv.reshape(L, -1)
    salt_m = codec_lib.slot_salt(key, step, 0, leaf_ids).reshape(L)
    salt_v = codec_lib.slot_salt(key, step, 1, leaf_ids).reshape(L)
    pn = prev_norm.reshape(L)
    bm = kernel.q8_row_block(mm, nn, level, block)
    kw = dict(level=level, block=block, gamma=gamma,
              use_limiter=use_limiter, weight_decay=weight_decay != 0,
              b1=b1, b2=b2, eps=eps)
    if impl in ("pallas", "interpret") and bm is not None:
        new_p, qm2, smo, qv2, svo, new_norm = kernel.gwt_adam_tile_fused_q8(
            g3, p3, qm3, sm2, qv3, sv2, salt_m, salt_v, pn, step_size,
            wd_coef, interpret=impl == "interpret", **kw)
    else:
        new_p, qm2, smo, qv2, svo, new_norm = ref.gwt_adam_fused_q8(
            g3, p3, qm3, sm2, qv3, sv2, salt_m, salt_v, pn, step_size,
            wd_coef, bm=bm if bm is not None else mm, **kw)
    new_p = new_p.reshape(gshape)
    qshape = gshape[:-1] + (nn >> level,)
    qm2, qv2 = qm2.reshape(qshape), qv2.reshape(qshape)
    smo, svo = smo.reshape(sm.shape), svo.reshape(sv.shape)
    if lead2:
        new_p, qm2, qv2 = new_p[0], qm2[0], qv2[0]
        new_norm = new_norm.reshape(())
    return new_p, new_norm, {"m": {"q": qm2, "scale": smo},
                             "v": {"q": qv2, "scale": svo}}
