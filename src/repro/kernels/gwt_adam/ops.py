"""jit'd wrapper for the fused GWT-Adam kernel, with backend dispatch and
leading-batch handling: any leading dims — stacked ``(L, m, n)`` scan
parameters *and* the optimizer engine's shape buckets — are flattened and
vmapped, so one call serves a whole bucket (one launch per bucket, not per
leaf).

``fused_update`` is the entry point used by ``repro.core.gwt`` when
``impl='pallas'`` (the GWT rules' ``vector_update``: the engine hands it
the full ``(L, m, n)`` stack in a single call).  Semantics match
``repro.core.gwt._gwt_core`` exactly (tested leaf-by-leaf); the
norm-growth limiter stays in the caller (vmapped per leaf).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels.gwt_adam import kernel, ref


def _tile_fn(impl: str, level: int, b1: float, b2: float, eps: float):
    impl = compat.resolve_kernel_impl(impl)
    if impl == "pallas":
        return functools.partial(kernel.gwt_adam_tile, level=level, b1=b1,
                                 b2=b2, eps=eps)
    if impl == "interpret":
        return functools.partial(kernel.gwt_adam_tile, level=level, b1=b1,
                                 b2=b2, eps=eps, interpret=True)
    return functools.partial(ref.gwt_adam_tile, level=level, b1=b1, b2=b2,
                             eps=eps)


def fused_update(g: jax.Array, state: dict, step: jax.Array, *,
                 level: int, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-6, impl: str = "auto"
                 ) -> Tuple[jax.Array, jax.Array, dict]:
    """Returns ``(g_tilde, lr_mult, new_state)`` — drop-in for the jnp core.

    ``impl``: auto|pallas|interpret|jnp — 'auto' resolves per platform via
    repro.compat (launchers pass MeshContext.kernel_impl explicitly).
    Resolution happens OUTSIDE the jitted body: 'auto' as a static jit arg
    would freeze the REPRO_KERNEL_IMPL env read into the trace cache."""
    impl = compat.resolve_kernel_impl(impl)
    return _fused_update(g, state, step, level=level, b1=b1, b2=b2, eps=eps,
                         impl=impl)


@functools.partial(jax.jit, static_argnames=("level", "b1", "b2", "eps", "impl"))
def _fused_update(g, state, step, *, level, b1, b2, eps, impl):
    fn = _tile_fn(impl, level, b1, b2, eps)
    if g.ndim > 2:  # stacked scan leaves (L, m, n)
        lead = g.shape[:-2]
        g2 = g.reshape((-1,) + g.shape[-2:])
        m2 = state["m"].reshape((-1,) + state["m"].shape[-2:])
        v2 = state["v"].reshape((-1,) + state["v"].shape[-2:])
        gt, m, v, _ = jax.vmap(fn)(g2, m2, v2)
        gt = gt.reshape(lead + gt.shape[-2:])
        m = m.reshape(lead + m.shape[-2:])
        v = v.reshape(lead + v.shape[-2:])
    else:
        gt, m, v, _ = fn(g, state["m"], state["v"])
    t = step.astype(jnp.float32) + 1.0
    lr_mult = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)
    return gt, lr_mult, {"m": m, "v": v}
