"""Pure-jnp oracle for the fused GWT-Adam kernel (Algorithm 1 inner loop).

Jitted as a whole so the oracle and the (whole-body-compiled) Pallas
kernel see identical XLA fusion/contraction decisions: run eagerly, each
op rounds separately and near-cancelling approximation coefficients can
land one f32 ulp away from the kernel's — which the ``1/(√V+ε)`` detail
scaling then amplifies across a bf16 rounding boundary (a single-element
8192-magnitude mismatch at ~2^20 magnitudes).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import haar


@functools.partial(jax.jit, static_argnames=("level", "b1", "b2", "eps"))
def gwt_adam_tile(g: jax.Array, m_st: jax.Array, v_st: jax.Array, *,
                  level: int, b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-6) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    g32 = g.astype(jnp.float32)
    a, details = haar.haar_forward(g32, level)
    m = b1 * m_st.astype(jnp.float32) + (1 - b1) * a
    v = b2 * v_st.astype(jnp.float32) + (1 - b2) * a * a
    inv_denom = 1.0 / (jnp.sqrt(v) + eps)
    a_t = m * inv_denom
    tilde_d = [d * haar.detail_scale_upsample(inv_denom, level, level - i)
               for i, d in enumerate(details)]
    gt = haar.haar_inverse(a_t, tilde_d).astype(g.dtype)
    # limiter norm partials over the ROUNDED output — the norm of the g̃
    # actually emitted, matching the kernel's ssq_ref
    gr = gt.astype(jnp.float32)
    ssq = jnp.sum(gr * gr)[None, None]
    return (gt, m.astype(m_st.dtype), v.astype(v_st.dtype), ssq)


@functools.partial(jax.jit, static_argnames=("level", "block", "b1", "b2",
                                             "eps"))
def gwt_adam_tile_q8(g: jax.Array, qm: jax.Array, sm: jax.Array,
                     qv: jax.Array, sv: jax.Array,
                     salt_m: jax.Array, salt_v: jax.Array, *,
                     level: int, block: int, b1: float = 0.9,
                     b2: float = 0.999, eps: float = 1e-6):
    """q8 oracle: blocked-int8 moments in, blocked-int8 moments out.

    Dequantize → ``gwt_adam_tile`` math → stochastic requantize with the
    caller-supplied per-slot salts (``repro.optim.codec`` hash — the same
    bits the Pallas epilogue and the engine's generic scan wrap produce).
    Returns ``(gt, qm', sm', qv', sv', ssq)``.
    """
    from repro.optim import codec as codec_lib
    m_st = codec_lib.blocked_dequant(qm, sm, block)
    v_st = codec_lib.blocked_dequant(qv, sv, block)
    g32 = g.astype(jnp.float32)
    a, details = haar.haar_forward(g32, level)
    m = b1 * m_st + (1 - b1) * a
    v = b2 * v_st + (1 - b2) * a * a
    inv_denom = 1.0 / (jnp.sqrt(v) + eps)
    a_t = m * inv_denom
    tilde_d = [d * haar.detail_scale_upsample(inv_denom, level, level - i)
               for i, d in enumerate(details)]
    gt = haar.haar_inverse(a_t, tilde_d).astype(g.dtype)
    gr = gt.astype(jnp.float32)
    ssq = jnp.sum(gr * gr)[None, None]
    qm2, sm2 = codec_lib.blocked_quant(m, salt_m, block)
    qv2, sv2 = codec_lib.blocked_quant(v, salt_v, block)
    return (gt, qm2, sm2, qv2, sv2, ssq)
