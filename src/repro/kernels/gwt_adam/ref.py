"""Pure-jnp oracle for the fused GWT-Adam kernel (Algorithm 1 inner loop)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import haar


def gwt_adam_tile(g: jax.Array, m_st: jax.Array, v_st: jax.Array, *,
                  level: int, b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-6) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    g32 = g.astype(jnp.float32)
    a, details = haar.haar_forward(g32, level)
    m = b1 * m_st.astype(jnp.float32) + (1 - b1) * a
    v = b2 * v_st.astype(jnp.float32) + (1 - b2) * a * a
    inv_denom = 1.0 / (jnp.sqrt(v) + eps)
    a_t = m * inv_denom
    tilde_d = [d * haar.detail_scale_upsample(inv_denom, level, level - i)
               for i, d in enumerate(details)]
    gt = haar.haar_inverse(a_t, tilde_d)
    ssq = jnp.sum(gt * gt)[None, None]
    return (gt.astype(g.dtype), m.astype(m_st.dtype), v.astype(v_st.dtype), ssq)
