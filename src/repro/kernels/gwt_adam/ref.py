"""Pure-jnp oracle for the fused GWT-Adam kernel (Algorithm 1 inner loop).

Jitted as a whole so the oracle and the (whole-body-compiled) Pallas
kernel see identical XLA fusion/contraction decisions: run eagerly, each
op rounds separately and near-cancelling approximation coefficients can
land one f32 ulp away from the kernel's — which the ``1/(√V+ε)`` detail
scaling then amplifies across a bf16 rounding boundary (a single-element
8192-magnitude mismatch at ~2^20 magnitudes).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import haar


@functools.partial(jax.jit, static_argnames=("level", "b1", "b2", "eps"))
def gwt_adam_tile(g: jax.Array, m_st: jax.Array, v_st: jax.Array, *,
                  level: int, b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-6) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    g32 = g.astype(jnp.float32)
    a, details = haar.haar_forward(g32, level)
    m = b1 * m_st.astype(jnp.float32) + (1 - b1) * a
    v = b2 * v_st.astype(jnp.float32) + (1 - b2) * a * a
    inv_denom = 1.0 / (jnp.sqrt(v) + eps)
    a_t = m * inv_denom
    tilde_d = [d * haar.detail_scale_upsample(inv_denom, level, level - i)
               for i, d in enumerate(details)]
    gt = haar.haar_inverse(a_t, tilde_d).astype(g.dtype)
    # limiter norm partials over the ROUNDED output — the norm of the g̃
    # actually emitted, matching the kernel's ssq_ref
    gr = gt.astype(jnp.float32)
    ssq = jnp.sum(gr * gr)[None, None]
    return (gt, m.astype(m_st.dtype), v.astype(v_st.dtype), ssq)


@functools.partial(jax.jit, static_argnames=("level", "block", "b1", "b2",
                                             "eps"))
def gwt_adam_tile_q8(g: jax.Array, qm: jax.Array, sm: jax.Array,
                     qv: jax.Array, sv: jax.Array,
                     salt_m: jax.Array, salt_v: jax.Array, *,
                     level: int, block: int, b1: float = 0.9,
                     b2: float = 0.999, eps: float = 1e-6):
    """q8 oracle: blocked-int8 moments in, blocked-int8 moments out.

    Dequantize → ``gwt_adam_tile`` math → stochastic requantize with the
    caller-supplied per-slot salts (``repro.optim.codec`` hash — the same
    bits the Pallas epilogue and the engine's generic scan wrap produce).
    Returns ``(gt, qm', sm', qv', sv', ssq)``.
    """
    from repro.optim import codec as codec_lib
    m_st = codec_lib.blocked_dequant(qm, sm, block)
    v_st = codec_lib.blocked_dequant(qv, sv, block)
    g32 = g.astype(jnp.float32)
    a, details = haar.haar_forward(g32, level)
    m = b1 * m_st + (1 - b1) * a
    v = b2 * v_st + (1 - b2) * a * a
    inv_denom = 1.0 / (jnp.sqrt(v) + eps)
    a_t = m * inv_denom
    tilde_d = [d * haar.detail_scale_upsample(inv_denom, level, level - i)
               for i, d in enumerate(details)]
    gt = haar.haar_inverse(a_t, tilde_d).astype(g.dtype)
    gr = gt.astype(jnp.float32)
    ssq = jnp.sum(gr * gr)[None, None]
    qm2, sm2 = codec_lib.blocked_quant(m, salt_m, block)
    qv2, sv2 = codec_lib.blocked_quant(v, salt_v, block)
    return (gt, qm2, sm2, qv2, sv2, ssq)


# ---------------------------------------------------------------------------
# Fused-write (megakernel) oracles.  These replicate the kernel's exact
# computation *shape* — per-(bm, n) row-stripe ssq partials accumulated
# left-to-right — so the interpret backend bitwise-matches them: the only
# order-sensitive op in the whole fused chain is the norm reduction, and
# pinning its association to the kernel's tiling makes the parity exact
# rather than ulp-close.  ``bm`` must be the kernel's row-block choice
# (ops.py passes ``kernel.fused_row_block`` / ``kernel.q8_row_block``).
# ---------------------------------------------------------------------------

def _tiled_norm(gt: jax.Array, bm: int) -> jax.Array:
    """‖gt‖ via the kernel's reduction order: one ``jnp.sum`` per (bm, n)
    row stripe, partials added sequentially."""
    xr = gt.astype(jnp.float32)
    acc = None
    for k in range(gt.shape[0] // bm):
        t = xr[k * bm:(k + 1) * bm]
        part = jnp.sum(t * t)
        acc = part if acc is None else acc + part
    return jnp.sqrt(acc)


def _limit_write(gt, p, prev, step_size, wd_coef, *, gamma, use_limiter,
                 weight_decay, bm):
    from repro.kernels.gwt_adam import kernel
    if use_limiter:
        norm = _tiled_norm(gt, bm)
        scale = kernel._limiter_scale(norm, prev, gamma)
        new_norm = jnp.where(norm > 0, norm * scale, prev)
    else:
        scale = jnp.float32(1.0)
        new_norm = prev
    limited = gt * scale.astype(gt.dtype)
    p32 = p.astype(jnp.float32)
    new_p = p32 - step_size * limited.astype(jnp.float32)
    if weight_decay:
        new_p = new_p - wd_coef * p32
    return new_p.astype(p.dtype), new_norm


@functools.partial(jax.jit, static_argnames=(
    "level", "gamma", "use_limiter", "weight_decay", "bm", "b1", "b2", "eps"))
def gwt_adam_fused(g: jax.Array, p: jax.Array, m_st: jax.Array,
                   v_st: jax.Array, prev_norm: jax.Array,
                   step_size: jax.Array, wd_coef: jax.Array, *,
                   level: int, gamma: float, use_limiter: bool,
                   weight_decay: bool, bm: int, b1: float = 0.9,
                   b2: float = 0.999, eps: float = 1e-6):
    """Fused-write oracle over a stacked ``(L, m, n)`` bucket.  Returns
    ``(new_p, new_m, new_v, new_norm)`` with ``new_norm`` f32 ``(L,)``.

    ``p``/``m``/``v`` ride the ``lax.scan`` carry and are updated leaf-by-
    leaf with in-place dynamic-update-slice, so one leaf's working set is
    the only live temp and donated inputs alias straight through to the
    outputs — the one-launch dataflow the kernel has, visible to XLA
    buffer assignment (the step benchmark's fused-vs-staged peak-live
    gate rides on this)."""
    def body(carry, xs):
        p_c, m_c, v_c = carry
        gl, pnl, l = xs
        gt, m, v, _ = gwt_adam_tile(
            gl, jax.lax.dynamic_index_in_dim(m_c, l, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(v_c, l, 0, keepdims=False),
            level=level, b1=b1, b2=b2, eps=eps)
        new_p, new_norm = _limit_write(
            gt, jax.lax.dynamic_index_in_dim(p_c, l, 0, keepdims=False),
            pnl, step_size, wd_coef, gamma=gamma, use_limiter=use_limiter,
            weight_decay=weight_decay, bm=bm)
        p_c = jax.lax.dynamic_update_index_in_dim(p_c, new_p, l, 0)
        m_c = jax.lax.dynamic_update_index_in_dim(m_c, m, l, 0)
        v_c = jax.lax.dynamic_update_index_in_dim(v_c, v, l, 0)
        return (p_c, m_c, v_c), new_norm
    idx = jnp.arange(g.shape[0], dtype=jnp.int32)
    (p, m_st, v_st), norms = jax.lax.scan(
        body, (p, m_st, v_st), (g, prev_norm, idx))
    return p, m_st, v_st, norms


@functools.partial(jax.jit, static_argnames=(
    "level", "block", "gamma", "use_limiter", "weight_decay", "bm",
    "b1", "b2", "eps"))
def gwt_adam_fused_q8(g: jax.Array, p: jax.Array, qm: jax.Array,
                      sm: jax.Array, qv: jax.Array, sv: jax.Array,
                      salt_m: jax.Array, salt_v: jax.Array,
                      prev_norm: jax.Array, step_size: jax.Array,
                      wd_coef: jax.Array, *, level: int, block: int,
                      gamma: float, use_limiter: bool, weight_decay: bool,
                      bm: int, b1: float = 0.9, b2: float = 0.999,
                      eps: float = 1e-6):
    """q8 fused-write oracle (blocked-int8 moments in/out).  Returns
    ``(new_p, qm', sm', qv', sv', new_norm)``.

    Same ``lax.scan`` carry structure as :func:`gwt_adam_fused` —
    ``p``/``qm``/``sm``/``qv``/``sv`` update in-place leaf-by-leaf so
    donated inputs alias through and one leaf bounds the live temps."""
    def body(carry, xs):
        p_c, qm_c, sm_c, qv_c, sv_c = carry
        gl, saltml, saltvl, pnl, l = xs
        at = lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)
        gt, qm2, sm2, qv2, sv2, _ = gwt_adam_tile_q8(
            gl, at(qm_c), at(sm_c), at(qv_c), at(sv_c), saltml, saltvl,
            level=level, block=block, b1=b1, b2=b2, eps=eps)
        new_p, new_norm = _limit_write(
            gt, at(p_c), pnl, step_size, wd_coef, gamma=gamma,
            use_limiter=use_limiter, weight_decay=weight_decay, bm=bm)
        upd = jax.lax.dynamic_update_index_in_dim
        return ((upd(p_c, new_p, l, 0), upd(qm_c, qm2, l, 0),
                 upd(sm_c, sm2, l, 0), upd(qv_c, qv2, l, 0),
                 upd(sv_c, sv2, l, 0)), new_norm)
    idx = jnp.arange(g.shape[0], dtype=jnp.int32)
    (p, qm, sm, qv, sv), norms = jax.lax.scan(
        body, (p, qm, sm, qv, sv), (g, salt_m, salt_v, prev_norm, idx))
    return p, qm, sm, qv, sv, norms
