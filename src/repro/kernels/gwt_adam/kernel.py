"""Pallas TPU kernel: FUSED GWT-Adam update (the paper's Algorithm 1 inner
loop, beyond-paper fusion).

Per ``(bm, bn)`` gradient tile, in a single VMEM residency:

    forward Haar butterfly (all ``l`` levels)      [bands stay in registers]
    M ← β₁M + (1−β₁)A ;  V ← β₂V + (1−β₂)A²        [moment tiles bn/2^l wide]
    Ã = M/(√V+ε) ;  D̃_k = D_k · repeat(1/(√V+ε))
    inverse butterfly → G̃ tile
    partial ‖G̃‖² per tile                          [for the norm-growth limiter]

HBM traffic: read G (bm·bn) + read/write M,V (2·bm·bn/2^l each) + write G̃
(bm·bn) ≈ ``2 + 4/2^l`` elements per gradient element — vs ``≥ 6`` for the
unfused op-by-op schedule (read G, write A/D, read A/D + M/V, write M/V/Ã/D̃,
read Ã/D̃, write G̃).  The op does O(1) FLOPs/element, so on TPU v5e it is
purely HBM-bandwidth-bound and the fusion is a ~2.5× win at l=2 (measured
as bytes, see EXPERIMENTS.md §Perf).

The detail bands are *never* materialized in HBM — exactly the paper's
"temporary information generated during the wavelet transform" observation
(§V), taken to its architectural conclusion.

Bias correction (``lr_mult``) and the norm-growth limiter ratio are scalars
applied by the caller (ops.py) — the limiter needs the global norm, which is
reduced from the per-tile partials this kernel emits.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INV_SQRT2 = 0.7071067811865476


def _body(level: int, b1: float, b2: float, eps: float,
          g_ref, m_ref, v_ref,
          gt_ref, m_out_ref, v_out_ref, ssq_ref):
    x = g_ref[...].astype(jnp.float32)
    bm, bn = x.shape

    # ---- forward butterfly, keep all detail bands in registers ----
    a = x
    details = []
    for _ in range(level):
        pairs = a.reshape(bm, a.shape[-1] // 2, 2)
        even, odd = pairs[..., 0], pairs[..., 1]
        a = (even + odd) * INV_SQRT2
        details.append((even - odd) * INV_SQRT2)  # [D_1 .. D_l] (fine->coarse)

    # ---- Adam moment update on the approximation band ----
    m = b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * a
    v = b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * a * a
    inv_denom = 1.0 / (jnp.sqrt(v) + eps)
    a_t = m * inv_denom

    # ---- scale details by the upsampled preconditioner, inverse butterfly --
    x = a_t
    for k in range(level, 0, -1):          # coarsest band first
        d = details[k - 1]
        reps = 1 << (level - k)
        scale = inv_denom if reps == 1 else jnp.repeat(inv_denom, reps, axis=-1)
        d_t = d * scale
        even = (x + d_t) * INV_SQRT2
        odd = (x - d_t) * INV_SQRT2
        x = jnp.stack([even, odd], axis=-1).reshape(bm, x.shape[-1] * 2)

    out = x.astype(gt_ref.dtype)
    gt_ref[...] = out
    m_out_ref[...] = m.astype(m_out_ref.dtype)
    v_out_ref[...] = v.astype(v_out_ref.dtype)
    # limiter norm partials over the ROUNDED output tile (matches ref.py):
    # the limiter should see the norm of the g̃ actually written to HBM
    xr = out.astype(jnp.float32)
    ssq_ref[0, 0] = jnp.sum(xr * xr)


def _pick_blocks(m: int, n: int, level: int) -> Tuple[int, int]:
    unit = max(1 << level, 128)
    bn = unit
    while bn * 2 <= min(n, 2048) and n % (bn * 2) == 0:
        bn *= 2
    if n % bn != 0:
        bn = n
    bm = 8
    # working set ≈ (G + bands + G̃ + M,V) ≈ 3.5·bm·bn·4B; cap ~4MB
    while bm * 2 <= min(m, 1024) and m % (bm * 2) == 0 \
            and 4 * (bm * 2) * bn * 4 <= 4 * 1024 * 1024:
        bm *= 2
    if m % bm != 0:
        bm = m
    return bm, bn


def gwt_adam_tile(g: jax.Array, m_st: jax.Array, v_st: jax.Array, *,
                  level: int, b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-6, interpret: bool = False
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused update for one 2-D leaf.

    Returns ``(g_tilde, new_m, new_v, sumsq_partials)`` where
    ``sumsq_partials`` has shape ``(grid_m, grid_n)`` (caller sums → ‖G̃‖²).
    """
    mm, nn = g.shape
    if nn % (1 << level) != 0:
        raise ValueError(f"n={nn} not divisible by 2^{level}")
    bm, bn = _pick_blocks(mm, nn, level)
    gm, gn = mm // bm, nn // bn
    bna = bn >> level
    return pl.pallas_call(
        functools.partial(_body, level, b1, b2, eps),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bna), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bna), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bna), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bna), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, nn), g.dtype),
            jax.ShapeDtypeStruct((mm, nn >> level), m_st.dtype),
            jax.ShapeDtypeStruct((mm, nn >> level), v_st.dtype),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ],
        interpret=interpret,
    )(g, m_st, v_st)
