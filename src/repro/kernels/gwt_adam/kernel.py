"""Pallas TPU kernel: FUSED GWT-Adam update (the paper's Algorithm 1 inner
loop, beyond-paper fusion).

Per ``(bm, bn)`` gradient tile, in a single VMEM residency:

    forward Haar butterfly (all ``l`` levels)      [bands stay in registers]
    M ← β₁M + (1−β₁)A ;  V ← β₂V + (1−β₂)A²        [moment tiles bn/2^l wide]
    Ã = M/(√V+ε) ;  D̃_k = D_k · repeat(1/(√V+ε))
    inverse butterfly → G̃ tile
    partial ‖G̃‖² per tile                          [for the norm-growth limiter]

HBM traffic: read G (bm·bn) + read/write M,V (2·bm·bn/2^l each) + write G̃
(bm·bn) ≈ ``2 + 4/2^l`` elements per gradient element — vs ``≥ 6`` for the
unfused op-by-op schedule (read G, write A/D, read A/D + M/V, write M/V/Ã/D̃,
read Ã/D̃, write G̃).  The op does O(1) FLOPs/element, so on TPU v5e it is
purely HBM-bandwidth-bound and the fusion is a ~2.5× win at l=2 (measured
as bytes, see EXPERIMENTS.md §Perf).

The detail bands are *never* materialized in HBM — exactly the paper's
"temporary information generated during the wavelet transform" observation
(§V), taken to its architectural conclusion.

Bias correction (``lr_mult``) and the norm-growth limiter ratio are scalars
applied by the caller (ops.py) — the limiter needs the global norm, which is
reduced from the per-tile partials this kernel emits.

**Fused-write megakernel** (``gwt_adam_tile_fused{,_q8}``): the full
DWT→Adam→inverse→limit→param-write chain in ONE launch per ``(L, m, n)``
bucket.  The leaf axis is folded into the grid (no vmap), the per-leaf
``‖G̃‖`` reduction runs as a two-phase pass over the row tiles with the
``new_norm`` output block as the on-chip accumulator (all ``phases·gm``
grid steps of leaf ``l`` map it to the same block — consecutive revisits
keep it resident in VMEM on TPU), and the epilogue applies the norm-growth
limiter, the bias-corrected step size, and weight decay before writing the
parameter tile.  ``G̃`` never round-trips HBM and the gradient never lives
alongside its transform.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.optim import codec as codec_lib

INV_SQRT2 = 0.7071067811865476


def _body(level: int, b1: float, b2: float, eps: float,
          g_ref, m_ref, v_ref,
          gt_ref, m_out_ref, v_out_ref, ssq_ref):
    x = g_ref[...].astype(jnp.float32)
    out, m, v = _dht_adam_core(x, m_ref[...].astype(jnp.float32),
                               v_ref[...].astype(jnp.float32),
                               level, b1, b2, eps)
    out = out.astype(gt_ref.dtype)
    gt_ref[...] = out
    m_out_ref[...] = m.astype(m_out_ref.dtype)
    v_out_ref[...] = v.astype(v_out_ref.dtype)
    # limiter norm partials over the ROUNDED output tile (matches ref.py):
    # the limiter should see the norm of the g̃ actually written to HBM
    xr = out.astype(jnp.float32)
    ssq_ref[0, 0] = jnp.sum(xr * xr)


def _pick_blocks(m: int, n: int, level: int) -> Tuple[int, int]:
    unit = max(1 << level, 128)
    bn = unit
    while bn * 2 <= min(n, 2048) and n % (bn * 2) == 0:
        bn *= 2
    if n % bn != 0:
        bn = n
    bm = 8
    # working set ≈ (G + bands + G̃ + M,V) ≈ 3.5·bm·bn·4B; cap ~4MB
    while bm * 2 <= min(m, 1024) and m % (bm * 2) == 0 \
            and 4 * (bm * 2) * bn * 4 <= 4 * 1024 * 1024:
        bm *= 2
    if m % bm != 0:
        bm = m
    return bm, bn


def _dht_adam_core(x, m_st, v_st, level, b1, b2, eps):
    """Forward butterfly → Adam on A → scaled-detail inverse butterfly.
    Shared by the f32 body and the q8 (blocked-int8 moments) body."""
    bm = x.shape[0]
    a = x
    details = []
    for _ in range(level):
        pairs = a.reshape(bm, a.shape[-1] // 2, 2)
        even, odd = pairs[..., 0], pairs[..., 1]
        a = (even + odd) * INV_SQRT2
        details.append((even - odd) * INV_SQRT2)

    m = b1 * m_st + (1.0 - b1) * a
    v = b2 * v_st + (1.0 - b2) * a * a
    inv_denom = 1.0 / (jnp.sqrt(v) + eps)

    x = m * inv_denom
    for k in range(level, 0, -1):
        d = details[k - 1]
        reps = 1 << (level - k)
        scale = inv_denom if reps == 1 else jnp.repeat(inv_denom, reps, axis=-1)
        d_t = d * scale
        even = (x + d_t) * INV_SQRT2
        odd = (x - d_t) * INV_SQRT2
        x = jnp.stack([even, odd], axis=-1).reshape(bm, x.shape[-1] * 2)
    return x, m, v


def _body_q8(level: int, b1: float, b2: float, eps: float, block: int,
             g_ref, qm_ref, sm_ref, qv_ref, sv_ref, saltm_ref, saltv_ref,
             gt_ref, qm_out_ref, sm_out_ref, qv_out_ref, sv_out_ref,
             ssq_ref):
    """q8 body: dequantize blocked-int8 moment tiles, run the fused DHT-Adam
    core, stochastically requantize in the epilogue.  The grid tiles ROWS
    only (full-width blocks), so each tile's row-major flat range is
    block-aligned and scale blocks never straddle tiles."""
    x = g_ref[...].astype(jnp.float32)
    bm, bn = x.shape
    bna = bn >> level
    sb = (bm * bna) // block

    def dequant(q_ref, s_ref):
        q = q_ref[...].astype(jnp.float32).reshape(sb, block)
        return (q * s_ref[...][:, 0][:, None]).reshape(bm, bna)

    out, m, v = _dht_adam_core(x, dequant(qm_ref, sm_ref),
                               dequant(qv_ref, sv_ref), level, b1, b2, eps)

    gt = out.astype(gt_ref.dtype)
    gt_ref[...] = gt
    xr = gt.astype(jnp.float32)
    ssq_ref[0, 0] = jnp.sum(xr * xr)

    # ---- requant epilogue: global flat element index -> rounding bits ----
    base = pl.program_id(0) * (bm * bna)
    idx = (base
           + jax.lax.broadcasted_iota(jnp.int32, (sb, block), 0) * block
           + jax.lax.broadcasted_iota(jnp.int32, (sb, block), 1))

    def requant(arr, salt, q_out, s_out):
        blocks = arr.reshape(sb, block)
        absmax = jnp.max(jnp.abs(blocks), axis=1)
        scale = absmax * jnp.float32(1.0 / 127.0)
        inv = jnp.where(scale > 0, 1.0 / scale, 0.0).astype(jnp.float32)
        y = blocks * inv[:, None]
        lo = jnp.floor(y)
        q = lo + (codec_lib.uniform01(salt, idx) < (y - lo)).astype(
            jnp.float32)
        q_out[...] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8).reshape(
            bm, bna)
        s_out[...] = scale[:, None]

    requant(m, saltm_ref[0, 0], qm_out_ref, sm_out_ref)
    requant(v, saltv_ref[0, 0], qv_out_ref, sv_out_ref)


def q8_row_block(m: int, n: int, level: int,
                 block: int) -> Optional[int]:
    """Row-tile height for the q8 kernel, or None when the shape cannot be
    tiled block-aligned (caller falls back to the jnp oracle).  ``bm`` must
    divide ``m`` and keep ``bm·na`` a multiple of ``block`` so per-tile
    scale slices are whole blocks."""
    na = n >> level
    if na == 0 or (m * na) % block != 0:
        return None
    step = block // math.gcd(na, block)
    best = None
    for bm in range(step, m + 1, step):
        if m % bm:
            continue
        if 4 * bm * n * 4 <= 4 * 1024 * 1024 or best is None:
            best = bm
        else:
            break
    return best


def gwt_adam_tile_q8(g: jax.Array, qm: jax.Array, sm: jax.Array,
                     qv: jax.Array, sv: jax.Array,
                     salt_m: jax.Array, salt_v: jax.Array, *,
                     level: int, block: int, b1: float = 0.9,
                     b2: float = 0.999, eps: float = 1e-6,
                     interpret: bool = False):
    """Fused q8 update for one 2-D leaf: blocked-int8 moments in/out.

    ``qm/qv``: int8 ``(m, n>>level)``; ``sm/sv``: f32 ``(nb,)`` flat-block
    scales; ``salt_m/salt_v``: uint32 rounding salts (slot-specific, from
    ``codec.slot_salt``).  Returns ``(gt, qm', sm', qv', sv', ssq)`` with
    ``ssq`` shaped ``(grid_m, 1)``.
    """
    mm, nn = g.shape
    if nn % (1 << level) != 0:
        raise ValueError(f"n={nn} not divisible by 2^{level}")
    bm = q8_row_block(mm, nn, level, block)
    if bm is None:
        raise ValueError(f"q8 kernel: ({mm},{nn}) level={level} not "
                         f"block-{block} alignable — use the jnp oracle")
    na = nn >> level
    nb = (mm * na) // block
    sb = (bm * na) // block
    gm = mm // bm
    sm2, sv2 = sm.reshape(nb, 1), sv.reshape(nb, 1)
    u32 = jnp.uint32
    saltm2 = jnp.asarray(salt_m, u32).reshape(1, 1)
    saltv2 = jnp.asarray(salt_v, u32).reshape(1, 1)
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    gt, qm2, smo, qv2, svo, ssq = pl.pallas_call(
        functools.partial(_body_q8, level, b1, b2, eps, block),
        grid=(gm,),
        in_specs=[
            pl.BlockSpec((bm, nn), lambda i: (i, 0)),
            pl.BlockSpec((bm, na), lambda i: (i, 0)),
            pl.BlockSpec((sb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, na), lambda i: (i, 0)),
            pl.BlockSpec((sb, 1), lambda i: (i, 0)),
            scalar, scalar,
        ],
        out_specs=[
            pl.BlockSpec((bm, nn), lambda i: (i, 0)),
            pl.BlockSpec((bm, na), lambda i: (i, 0)),
            pl.BlockSpec((sb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, na), lambda i: (i, 0)),
            pl.BlockSpec((sb, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, nn), g.dtype),
            jax.ShapeDtypeStruct((mm, na), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((mm, na), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((gm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(g, qm, sm2, qv, sv2, saltm2, saltv2)
    return gt, qm2, smo.reshape(nb), qv2, svo.reshape(nb), ssq


def gwt_adam_tile(g: jax.Array, m_st: jax.Array, v_st: jax.Array, *,
                  level: int, b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-6, interpret: bool = False
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused update for one 2-D leaf.

    Returns ``(g_tilde, new_m, new_v, sumsq_partials)`` where
    ``sumsq_partials`` has shape ``(grid_m, grid_n)`` (caller sums → ‖G̃‖²).
    """
    mm, nn = g.shape
    if nn % (1 << level) != 0:
        raise ValueError(f"n={nn} not divisible by 2^{level}")
    bm, bn = _pick_blocks(mm, nn, level)
    gm, gn = mm // bm, nn // bn
    bna = bn >> level
    return pl.pallas_call(
        functools.partial(_body, level, b1, b2, eps),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bna), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bna), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bna), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bna), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, nn), g.dtype),
            jax.ShapeDtypeStruct((mm, nn >> level), m_st.dtype),
            jax.ShapeDtypeStruct((mm, nn >> level), v_st.dtype),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ],
        interpret=interpret,
    )(g, m_st, v_st)


# ---------------------------------------------------------------------------
# Fused-write megakernel: one launch per (L, m, n) bucket does
# DWT -> Adam -> inverse -> norm-growth limiter -> parameter write.
# ---------------------------------------------------------------------------

def fused_row_block(m: int, n: int, level: int) -> int:
    """Row-tile height for the fused-write kernels: full-width stripes so
    the per-leaf ssq accumulation sees one tile per grid step.  Working set
    ≈ (G + P + G̃ + P' at width n, M,V in/out at width n>>level)
    ≈ (4 + 4/2^level)·bm·n·4B; cap ~4MB."""
    row_bytes = (4 + 4 / (1 << level)) * n * 4
    bm = 8 if m % 8 == 0 else m
    while bm * 2 <= min(m, 1024) and m % (bm * 2) == 0 \
            and (bm * 2) * row_bytes <= 4 * 1024 * 1024:
        bm *= 2
    return bm


def _limiter_scale(norm, prev, gamma: float):
    """The norm-growth limiter ratio — term-for-term ``core.limiter.limit``
    (bitwise parity with the staged path is a test invariant)."""
    safe_prev = jnp.where(prev > 0, prev, norm)
    return jnp.where(norm > gamma * safe_prev,
                     gamma * safe_prev / jnp.maximum(norm, 1e-30),
                     jnp.float32(1.0))


def _body_fused(level: int, b1: float, b2: float, eps: float, gamma: float,
                use_limiter: bool, wd: bool,
                g_ref, p_ref, m_ref, v_ref, pn_ref, ss_ref, wd_ref,
                p_out_ref, m_out_ref, v_out_ref, norm_ref):
    """Grid ``(L, phases, gm)`` — leaf outermost, row tiles innermost; the
    ``norm_ref`` output block (one per leaf, revisited every step of that
    leaf) doubles as the cross-tile ssq accumulator.  Phase 0 accumulates
    ``‖G̃_l‖²``; phase 1 recomputes the tile (the op is bandwidth-bound —
    recompute is cheaper than an HBM round trip of G̃) and applies
    limiter + step + weight decay + write.  ``use_limiter=False`` runs the
    single write phase only."""
    phase = pl.program_id(1)
    i = pl.program_id(2)
    gm = pl.num_programs(2)
    x = g_ref[0].astype(jnp.float32)
    out, m, v = _dht_adam_core(x, m_ref[0].astype(jnp.float32),
                               v_ref[0].astype(jnp.float32),
                               level, b1, b2, eps)
    gt = out.astype(g_ref.dtype)
    prev = pn_ref[0, 0]

    def write(scale):
        limited = gt * scale.astype(gt.dtype)
        p32 = p_ref[0].astype(jnp.float32)
        new_p = p32 - ss_ref[0, 0] * limited.astype(jnp.float32)
        if wd:
            new_p = new_p - wd_ref[0, 0] * p32
        p_out_ref[0] = new_p.astype(p_out_ref.dtype)
        m_out_ref[0] = m.astype(m_out_ref.dtype)
        v_out_ref[0] = v.astype(v_out_ref.dtype)

    if not use_limiter:
        write(jnp.float32(1.0))
        norm_ref[0, 0] = prev  # limiter off: prev_norm passes through
        return

    xr = gt.astype(jnp.float32)
    part = jnp.sum(xr * xr)

    @pl.when(phase == 0)
    def _():
        acc = jnp.where(i == 0, jnp.float32(0.0), norm_ref[0, 0])
        norm_ref[0, 0] = acc + part
        # On hardware, every output window a grid step maps is copied back
        # to HBM when the step ends, written or not — and p/m/v alias
        # their inputs, so leaving them unwritten here would clobber the
        # state phase 1 re-reads with undefined VMEM.  Pass the inputs
        # through unmodified (interpret mode masks this; the TPU parity
        # test below pins it).
        p_out_ref[0] = p_ref[0]
        m_out_ref[0] = m_ref[0]
        v_out_ref[0] = v_ref[0]

    @pl.when(phase == 1)
    def _():
        norm = jnp.sqrt(norm_ref[0, 0])
        scale = _limiter_scale(norm, prev, gamma)
        write(scale)

        @pl.when(i == gm - 1)
        def _():
            # zero-norm step preserves limiter history (core.limiter)
            norm_ref[0, 0] = jnp.where(norm > 0, norm * scale, prev)


def gwt_adam_tile_fused(g: jax.Array, p: jax.Array, m_st: jax.Array,
                        v_st: jax.Array, prev_norm: jax.Array,
                        step_size: jax.Array, wd_coef: jax.Array, *,
                        level: int, gamma: float, use_limiter: bool,
                        weight_decay: bool, b1: float = 0.9,
                        b2: float = 0.999, eps: float = 1e-6,
                        interpret: bool = False):
    """Fused-write update for a whole ``(L, m, n)`` bucket in ONE launch.

    ``prev_norm``: f32 ``(L,)`` per-leaf limiter state; ``step_size`` /
    ``wd_coef``: f32 scalars (bias-corrected lr·α and lr·weight_decay,
    computed by ops.py).  Returns ``(new_p, new_m, new_v, new_norm)`` with
    ``new_norm`` f32 ``(L,)``.
    """
    L, mm, nn = g.shape
    if nn % (1 << level) != 0:
        raise ValueError(f"n={nn} not divisible by 2^{level}")
    bm = fused_row_block(mm, nn, level)
    gm = mm // bm
    na = nn >> level
    phases = 2 if use_limiter else 1
    pn2 = prev_norm.astype(jnp.float32).reshape(L, 1)
    ss2 = jnp.asarray(step_size, jnp.float32).reshape(1, 1)
    wd2 = jnp.asarray(wd_coef, jnp.float32).reshape(1, 1)
    tile = lambda w: pl.BlockSpec((1, bm, w), lambda l, ph, i: (l, i, 0))
    leaf_scalar = pl.BlockSpec((1, 1), lambda l, ph, i: (l, 0))
    scalar = pl.BlockSpec((1, 1), lambda l, ph, i: (0, 0))
    new_p, new_m, new_v, new_norm = pl.pallas_call(
        functools.partial(_body_fused, level, b1, b2, eps, gamma,
                          use_limiter, weight_decay),
        grid=(L, phases, gm),
        in_specs=[tile(nn), tile(nn), tile(na), tile(na),
                  leaf_scalar, scalar, scalar],
        out_specs=[tile(nn), tile(na), tile(na), leaf_scalar],
        out_shape=[
            jax.ShapeDtypeStruct((L, mm, nn), p.dtype),
            jax.ShapeDtypeStruct((L, mm, na), m_st.dtype),
            jax.ShapeDtypeStruct((L, mm, na), v_st.dtype),
            jax.ShapeDtypeStruct((L, 1), jnp.float32),
        ],
        # in-place write semantics: p/m/v are updated in their own
        # buffers (each tile reads its block before writing it; phase 0
        # writes the inputs through unchanged).  NOT prev_norm→new_norm:
        # phase 0
        # accumulates ssq into the norm output while phase 1 still reads
        # the history from pn_ref — aliasing them would clobber it.
        input_output_aliases={1: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(g, p, m_st, v_st, pn2, ss2, wd2)
    return new_p, new_m, new_v, new_norm.reshape(L)


def _body_fused_q8(level: int, b1: float, b2: float, eps: float,
                   gamma: float, use_limiter: bool, wd: bool, block: int,
                   g_ref, p_ref, qm_ref, sm_ref, qv_ref, sv_ref,
                   saltm_ref, saltv_ref, pn_ref, ss_ref, wd_ref,
                   p_out_ref, qm_out_ref, sm_out_ref, qv_out_ref,
                   sv_out_ref, norm_ref):
    """q8 sibling of ``_body_fused``: blocked-int8 moments are dequantized
    in the prologue and stochastically requantized in the write phase (the
    rounding bits are a pure function of (salt, flat index), so the
    phase-1 recompute requantizes identically)."""
    phase = pl.program_id(1)
    i = pl.program_id(2)
    gm = pl.num_programs(2)
    x = g_ref[0].astype(jnp.float32)
    bm, bn = x.shape
    bna = bn >> level
    sb = (bm * bna) // block

    def dequant(q_ref, s_ref):
        q = q_ref[0].astype(jnp.float32).reshape(sb, block)
        return (q * s_ref[0][:, 0][:, None]).reshape(bm, bna)

    out, m, v = _dht_adam_core(x, dequant(qm_ref, sm_ref),
                               dequant(qv_ref, sv_ref), level, b1, b2, eps)
    gt = out.astype(g_ref.dtype)
    prev = pn_ref[0, 0]

    base = i * (bm * bna)
    idx = (base
           + jax.lax.broadcasted_iota(jnp.int32, (sb, block), 0) * block
           + jax.lax.broadcasted_iota(jnp.int32, (sb, block), 1))

    def requant(arr, salt, q_out, s_out):
        blocks = arr.reshape(sb, block)
        absmax = jnp.max(jnp.abs(blocks), axis=1)
        scale = absmax * jnp.float32(1.0 / 127.0)
        inv = jnp.where(scale > 0, 1.0 / scale, 0.0).astype(jnp.float32)
        y = blocks * inv[:, None]
        lo = jnp.floor(y)
        q = lo + (codec_lib.uniform01(salt, idx) < (y - lo)).astype(
            jnp.float32)
        q_out[0] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8).reshape(
            bm, bna)
        s_out[0] = scale[:, None]

    def write(scale):
        limited = gt * scale.astype(gt.dtype)
        p32 = p_ref[0].astype(jnp.float32)
        new_p = p32 - ss_ref[0, 0] * limited.astype(jnp.float32)
        if wd:
            new_p = new_p - wd_ref[0, 0] * p32
        p_out_ref[0] = new_p.astype(p_out_ref.dtype)
        requant(m, saltm_ref[0, 0], qm_out_ref, sm_out_ref)
        requant(v, saltv_ref[0, 0], qv_out_ref, sv_out_ref)

    if not use_limiter:
        write(jnp.float32(1.0))
        norm_ref[0, 0] = prev
        return

    xr = gt.astype(jnp.float32)
    part = jnp.sum(xr * xr)

    @pl.when(phase == 0)
    def _():
        acc = jnp.where(i == 0, jnp.float32(0.0), norm_ref[0, 0])
        norm_ref[0, 0] = acc + part
        # hardware copy-out of unwritten aliased windows would clobber
        # the state phase 1 re-reads — pass inputs through unmodified
        # (see _body_fused)
        p_out_ref[0] = p_ref[0]
        qm_out_ref[0] = qm_ref[0]
        sm_out_ref[0] = sm_ref[0]
        qv_out_ref[0] = qv_ref[0]
        sv_out_ref[0] = sv_ref[0]

    @pl.when(phase == 1)
    def _():
        norm = jnp.sqrt(norm_ref[0, 0])
        scale = _limiter_scale(norm, prev, gamma)
        write(scale)

        @pl.when(i == gm - 1)
        def _():
            norm_ref[0, 0] = jnp.where(norm > 0, norm * scale, prev)


def gwt_adam_tile_fused_q8(g: jax.Array, p: jax.Array, qm: jax.Array,
                           sm: jax.Array, qv: jax.Array, sv: jax.Array,
                           salt_m: jax.Array, salt_v: jax.Array,
                           prev_norm: jax.Array, step_size: jax.Array,
                           wd_coef: jax.Array, *, level: int, block: int,
                           gamma: float, use_limiter: bool,
                           weight_decay: bool, b1: float = 0.9,
                           b2: float = 0.999, eps: float = 1e-6,
                           interpret: bool = False):
    """Fused-write q8 update for a whole ``(L, m, n)`` bucket in one launch.

    ``qm/qv``: int8 ``(L, m, n>>level)``; ``sm/sv``: f32 ``(L, nb)``
    flat-block scales; ``salt_m/salt_v``: uint32 ``(L,)`` per-leaf slot
    salts.  Returns ``(new_p, qm', sm', qv', sv', new_norm)``.
    """
    L, mm, nn = g.shape
    if nn % (1 << level) != 0:
        raise ValueError(f"n={nn} not divisible by 2^{level}")
    bm = q8_row_block(mm, nn, level, block)
    if bm is None:
        raise ValueError(f"q8 fused kernel: ({mm},{nn}) level={level} not "
                         f"block-{block} alignable — use the jnp oracle")
    na = nn >> level
    nb = (mm * na) // block
    sb = (bm * na) // block
    gm = mm // bm
    phases = 2 if use_limiter else 1
    u32 = jnp.uint32
    sm3, sv3 = sm.reshape(L, nb, 1), sv.reshape(L, nb, 1)
    saltm2 = jnp.asarray(salt_m, u32).reshape(L, 1)
    saltv2 = jnp.asarray(salt_v, u32).reshape(L, 1)
    pn2 = prev_norm.astype(jnp.float32).reshape(L, 1)
    ss2 = jnp.asarray(step_size, jnp.float32).reshape(1, 1)
    wd2 = jnp.asarray(wd_coef, jnp.float32).reshape(1, 1)
    tile = lambda w: pl.BlockSpec((1, bm, w), lambda l, ph, i: (l, i, 0))
    stile = pl.BlockSpec((1, sb, 1), lambda l, ph, i: (l, i, 0))
    leaf_scalar = pl.BlockSpec((1, 1), lambda l, ph, i: (l, 0))
    scalar = pl.BlockSpec((1, 1), lambda l, ph, i: (0, 0))
    new_p, qm2, smo, qv2, svo, new_norm = pl.pallas_call(
        functools.partial(_body_fused_q8, level, b1, b2, eps, gamma,
                          use_limiter, weight_decay, block),
        grid=(L, phases, gm),
        in_specs=[tile(nn), tile(nn), tile(na), stile, tile(na), stile,
                  leaf_scalar, leaf_scalar, leaf_scalar, scalar, scalar],
        out_specs=[tile(nn), tile(na), stile, tile(na), stile, leaf_scalar],
        out_shape=[
            jax.ShapeDtypeStruct((L, mm, nn), p.dtype),
            jax.ShapeDtypeStruct((L, mm, na), jnp.int8),
            jax.ShapeDtypeStruct((L, nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((L, mm, na), jnp.int8),
            jax.ShapeDtypeStruct((L, nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((L, 1), jnp.float32),
        ],
        # in-place p and int8 payload/scale updates (reads precede writes
        # within each tile; phase 0 writes the inputs through unchanged).
        # prev_norm is deliberately NOT aliased to new_norm — see
        # gwt_adam_tile_fused.
        input_output_aliases={1: 0, 2: 1, 3: 2, 4: 3, 5: 4},
        interpret=interpret,
    )(g, p, qm, sm3, qv, sv3, saltm2, saltv2, pn2, ss2, wd2)
    return (new_p, qm2, smo.reshape(L, nb), qv2, svo.reshape(L, nb),
            new_norm.reshape(L))
