"""Version-portability shim for the JAX mesh/sharding API surface.

The reproduction targets the post-0.5 explicit-sharding API
(``get_abstract_mesh``, ``AxisType``, ``make_mesh(..., axis_types=...)``,
``set_mesh``/``use_mesh``) but must run unchanged on jax 0.4.x, which
predates all of them.  Every symbol here is resolved by *feature
detection* — probing the running JAX once at import — never by parsing
version strings, so point-release backports and renames keep working.

This module is the ONLY place in the repo allowed to touch those jax
symbols directly (enforced by a grep test in tests/test_compat.py).

Fallback semantics on older JAX:

* ``use_mesh(mesh)``      -> enters the concrete ``Mesh`` context manager
  (which makes bare-``PartitionSpec`` sharding constraints resolvable)
  and tracks the mesh on a thread-local stack.
* ``get_abstract_mesh()`` -> the stack top, else the thread-resources
  physical mesh (set by a raw ``with mesh:``), else ``None``.
* ``make_mesh``           -> drops ``axis_types`` (the older API has a
  single implicit behaviour equivalent to auto axes under GSPMD).
* ``with_sharding_constraint`` -> resolves bare specs against an explicit
  or ambient mesh via ``NamedSharding`` and degrades to a no-op when no
  mesh is available (CPU unit tests).

The same module owns kernel-backend selection (``pallas`` / ``interpret``
/ pure-``jnp``) so per-platform dispatch and the ``REPRO_KERNEL_IMPL``
override live next to the rest of the runtime-portability decisions.
"""

from __future__ import annotations

import contextlib
import enum
import inspect
import os
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def _probe(obj, name: str):
    """``getattr`` that treats jax's accelerated-deprecation
    ``AttributeError``s (raised from module ``__getattr__``) as absent."""
    try:
        return getattr(obj, name, None)
    except Exception:
        return None


# Feature flags — module-level so tests can monkeypatch each branch.
_NATIVE_AXIS_TYPE = _probe(jax.sharding, "AxisType")
_NATIVE_GET_ABSTRACT_MESH = _probe(jax.sharding, "get_abstract_mesh")
_NATIVE_USE_MESH = _probe(jax.sharding, "use_mesh") or _probe(jax, "set_mesh")
_NATIVE_MAKE_MESH = _probe(jax, "make_mesh")


def _accepts_axis_types(fn) -> bool:
    if fn is None:
        return False
    try:
        return "axis_types" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


_MAKE_MESH_AXIS_TYPES = _accepts_axis_types(_NATIVE_MAKE_MESH)


class _AxisTypeStub(enum.Enum):
    """Stand-in for the post-0.5 axis-type enum: call sites can name axis
    types symbolically even where the running JAX has no such concept."""
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = _NATIVE_AXIS_TYPE if _NATIVE_AXIS_TYPE is not None else _AxisTypeStub


def auto_axis_types(n: int):
    """``n`` auto axis types — the only variant this codebase uses."""
    return (AxisType.Auto,) * n


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types="auto", devices=None) -> Mesh:
    """Portable ``make_mesh``: passes ``axis_types`` only where the running
    JAX accepts it.  ``axis_types='auto'`` means all-auto (this repo's only
    use); ``None`` skips the argument entirely."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if axis_types == "auto":
        axis_types = auto_axis_types(len(axis_names))
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _NATIVE_MAKE_MESH is not None:
        if _MAKE_MESH_AXIS_TYPES and axis_types is not None:
            return _NATIVE_MAKE_MESH(axis_shapes, axis_names,
                                     axis_types=axis_types, **kw)
        return _NATIVE_MAKE_MESH(axis_shapes, axis_names, **kw)
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
    return Mesh(devs, axis_names)


# ---------------------------------------------------------------------------
# Ambient mesh: native abstract-mesh tracking where available, otherwise a
# thread-local stack maintained by use_mesh().
# ---------------------------------------------------------------------------

_ambient = threading.local()


def _stack():
    if not hasattr(_ambient, "meshes"):
        _ambient.meshes = []
    return _ambient.meshes


def get_abstract_mesh():
    """The ambient mesh, or ``None`` when no mesh context is active.

    Normalizes across versions: the native API returns an *empty* abstract
    mesh when unset — callers here always get ``None`` for "no mesh"."""
    if _NATIVE_GET_ABSTRACT_MESH is not None:
        m = _NATIVE_GET_ABSTRACT_MESH()
        if m is not None and tuple(getattr(m, "axis_names", ()) or ()):
            return m
        return None
    st = _stack()
    if st:
        return st[-1]
    try:  # a raw `with mesh:` (0.4.x resource env) also counts as ambient
        from jax._src import mesh as _mesh_src
        pm = _mesh_src.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    return None


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Context manager making ``mesh`` ambient.  ``None`` is a no-op (the
    single-device / CPU-unit-test case)."""
    if mesh is None:
        yield None
        return
    if _NATIVE_USE_MESH is not None:
        with _NATIVE_USE_MESH(mesh):
            yield mesh
        return
    st = _stack()
    st.append(mesh)
    try:
        if hasattr(mesh, "__enter__"):  # 0.4.x: resolves bare PartitionSpecs
            with mesh:
                yield mesh
        else:
            yield mesh
    finally:
        st.pop()


def unwrap_mesh(mesh_or_ctx):
    """Accept a Mesh/AbstractMesh OR an object carrying one (MeshContext);
    ``None`` passes through.  The single normalization point for APIs that
    take either."""
    return getattr(mesh_or_ctx, "mesh", mesh_or_ctx)


def with_sharding_constraint(x, *spec, mesh=None):
    """Sharding constraint that degrades to a no-op outside a mesh context.

    Bare axis names (or a ready ``PartitionSpec``) are resolved against the
    explicit ``mesh`` when given, else the ambient mesh.  A concrete mesh
    resolves through ``NamedSharding`` (works on every version without any
    ambient context); otherwise the bare spec is handed to jax, which the
    post-0.5 abstract-mesh machinery resolves itself."""
    if len(spec) == 1 and isinstance(spec[0], PartitionSpec):
        sp = spec[0]
    else:
        sp = PartitionSpec(*spec)
    m = mesh if mesh is not None else get_abstract_mesh()
    try:
        if isinstance(m, Mesh):
            return jax.lax.with_sharding_constraint(x, NamedSharding(m, sp))
        return jax.lax.with_sharding_constraint(x, sp)
    except (ValueError, RuntimeError, TypeError):
        return x


# ---------------------------------------------------------------------------
# shard_map: top-level jax.shard_map (0.6+, manual axes named via
# ``axis_names``) vs jax.experimental.shard_map.shard_map (0.4.x/0.5.x,
# manual-by-default with an ``auto`` complement set).
# ---------------------------------------------------------------------------

_NATIVE_SHARD_MAP = _probe(jax, "shard_map")


def _experimental_shard_map():
    from jax.experimental.shard_map import shard_map as sm
    return sm


def shard_map(f, mesh, in_specs, out_specs, auto=frozenset()):
    """Portable ``shard_map``: manualize every mesh axis except ``auto``
    (left to GSPMD — e.g. the tensor-parallel 'model' axis while the DP
    gradient reduction runs manually over 'data').

    Replication checking is disabled on every version: the call sites here
    produce post-``psum`` (replicated-by-construction) outputs that the
    checker cannot always prove through dtype casts, and 0.4.x rejects
    ``check_rep=True`` combined with non-empty ``auto``."""
    mesh = unwrap_mesh(mesh)
    auto = frozenset(auto)
    if _NATIVE_SHARD_MAP is not None:
        params = inspect.signature(_NATIVE_SHARD_MAP).parameters
        if "axis_names" in params:
            manual = frozenset(mesh.axis_names) - auto
            kw = {"axis_names": manual}
            if "check_vma" in params:
                kw["check_vma"] = False
            elif "check_rep" in params:
                kw["check_rep"] = False
            return _NATIVE_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, **kw)
        return _NATIVE_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False,
                                 auto=auto)
    return _experimental_shard_map()(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_rep=False,
                                     auto=auto)


# ---------------------------------------------------------------------------
# Compiled-artifact introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: newer jax returns a flat
    dict, 0.4.x a one-element list of per-program dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost or {})


# ---------------------------------------------------------------------------
# Kernel backend selection
# ---------------------------------------------------------------------------

KERNEL_IMPLS = ("pallas", "interpret", "jnp")


def default_kernel_impl(platform: Optional[str] = None) -> str:
    """Per-platform default backend: native Pallas on TPU, the pure-jnp
    butterfly elsewhere.  ``REPRO_KERNEL_IMPL`` overrides (e.g. set
    ``interpret`` to validate the Pallas lowering on CPU)."""
    env = os.environ.get("REPRO_KERNEL_IMPL", "").strip().lower()
    if env and env != "auto":
        if env not in KERNEL_IMPLS:  # fail fast: a typo here would
            # otherwise silently fall back to a different backend
            raise ValueError(
                f"REPRO_KERNEL_IMPL={env!r} invalid; choices: auto|" +
                "|".join(KERNEL_IMPLS))
        return env
    platform = platform or jax.default_backend()
    return "pallas" if platform == "tpu" else "jnp"


def resolve_kernel_impl(impl: Optional[str] = None,
                        platform: Optional[str] = None) -> str:
    """Map ``None``/``'auto'`` to the platform default; validate the rest."""
    if impl in (None, "auto"):
        return default_kernel_impl(platform)
    if impl not in KERNEL_IMPLS:
        raise ValueError(
            f"unknown kernel impl {impl!r}; choices: auto|" +
            "|".join(KERNEL_IMPLS))
    return impl
