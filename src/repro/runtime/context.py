"""Explicit mesh/runtime context threaded through the system.

``MeshContext`` is created ONCE at launch (train / dryrun / serve) and
passed explicitly through model apply, optimizer construction, sharding
rules, gradient compression, and checkpoint restore.  It bundles the two
runtime decisions that previously leaked through ambient globals:

* **which mesh** activations/params are constrained against (``mesh``,
  ``None`` = single device — every constraint becomes a no-op), and
* **which kernel backend** the fused GWT/Haar ops dispatch to
  (``kernel_impl``: ``pallas`` | ``interpret`` | ``jnp``, resolved from
  ``'auto'`` per platform via :mod:`repro.compat`).

Code not yet handed a context (CPU unit tests calling ``lm.forward``
directly) falls back to :meth:`MeshContext.ambient`, which reads the
compat-shimmed ambient mesh — the old implicit behaviour, now in exactly
one place.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

from repro import compat


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Immutable carrier of the launch-time mesh + kernel-backend choice."""

    mesh: object = None          # concrete Mesh, AbstractMesh, or None
    kernel_impl: str = "jnp"     # resolved: pallas | interpret | jnp

    # -- construction ------------------------------------------------------
    @classmethod
    def create(cls, mesh=None, kernel_impl: str = "auto") -> "MeshContext":
        return cls(mesh=mesh,
                   kernel_impl=compat.resolve_kernel_impl(kernel_impl))

    @classmethod
    def ambient(cls, kernel_impl: str = "auto") -> "MeshContext":
        """Compat-shimmed fallback for call sites without an explicit
        context: adopt whatever mesh is ambient (usually ``None``)."""
        return cls.create(mesh=compat.get_abstract_mesh(),
                          kernel_impl=kernel_impl)

    # -- mesh introspection ------------------------------------------------
    @property
    def axis_names(self) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(getattr(self.mesh, "axis_names", ()) or ())

    def has_axis(self, name: str) -> bool:
        return name in self.axis_names

    def axis_size(self, name: str) -> int:
        """Size of mesh axis ``name``; 0 when absent (no mesh / no axis)."""
        if not self.has_axis(name):
            return 0
        return int(self.mesh.shape[name])

    @property
    def dp_axis_names(self) -> Tuple[str, ...]:
        """Mesh axes carrying data parallelism, in reduction order
        (('pod', 'data'), ('data',), or () without a mesh)."""
        return tuple(a for a in ("pod", "data") if a in self.axis_names)

    @property
    def dp_size(self) -> int:
        """Total data-parallel degree (1 without a mesh)."""
        return math.prod(self.axis_size(a) for a in self.dp_axis_names) \
            if self.dp_axis_names else 1

    @property
    def auto_axis_names(self) -> Tuple[str, ...]:
        """Mesh axes left to GSPMD when the DP axes run manually under
        ``shard_map`` (the TP 'model' axis)."""
        dp = set(self.dp_axis_names)
        return tuple(a for a in self.axis_names if a not in dp)

    def dp_axes(self, nbatch: int) -> Optional[Union[str, Tuple[str, ...]]]:
        """DP mesh axes that divide ``nbatch`` (or None).

        Activation batch dims MUST be pinned explicitly: the FSDP-sharded
        embedding table (embed dim over 'data') otherwise propagates
        feature-over-data sharding into the stack and GSPMD settles on a
        replicated batch (measured: full-batch dots on every device)."""
        names = self.axis_names
        if not names:
            return None
        for cand in (("pod", "data"), ("data",)):
            if all(a in names for a in cand):
                if nbatch % math.prod(self.axis_size(a) for a in cand) == 0:
                    return cand if len(cand) > 1 else cand[0]
        return None

    # -- actions -----------------------------------------------------------
    def activate(self):
        """Context manager making ``mesh`` ambient (jit/lower under it)."""
        return compat.use_mesh(self.mesh)

    def constrain(self, x, *spec):
        """Sharding constraint against THIS context's mesh (no-op if none)."""
        return compat.with_sharding_constraint(x, *spec, mesh=self.mesh)
