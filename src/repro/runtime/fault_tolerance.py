"""Fault-tolerant training runtime: pipelined superstep train loop with
buffer donation, preemption handling, auto-resume, a dispatch/block-split
step watchdog, and an elastic re-mesh hook.

Designed for the 1000+-node posture (DESIGN.md §4):

* **Pipelined supersteps**: the loop dispatches a ``lax.scan`` over a
  *chunk* of train steps per device call, with ``(params, opt_state)``
  donated across chunks — host python (batch stacking, dispatch) amortizes
  over the chunk and the optimizer state is single-buffered end to end.
  Loss lands in an on-device ``(k,)`` accumulator; the host fetches it only
  at ``log_every`` boundaries, so dispatch never serializes on a per-step
  ``float()`` sync.
* **Deterministic chunk grid**: chunk boundaries are *absolute* step
  numbers (next multiple of ``log_every`` / ``ckpt_every`` / ``max_chunk``
  / ``num_steps``), never relative to where a run started.  A resumed run
  therefore re-executes the exact same scan groupings as an uninterrupted
  one — bit-identical final params (tested in test_runtime_pipeline.py).
* **Preemption**: SIGTERM/SIGINT set a flag; the loop checkpoints
  synchronously at the current chunk boundary and exits 0 (the scheduler
  restarts the job, which auto-resumes from the latest committed step).
* **Snapshot-then-save**: periodic checkpoints are taken from an on-device
  copy (``CheckpointManager.save(snapshot=True)``) so the async writer
  never races the next chunk's buffer donation.
* **Watchdog**: separate EMAs for *dispatch* time (async enqueue — what the
  host pays per step) and *blocked* time (host stalled on device results at
  log/checkpoint boundaries).  Straggler incidents are flagged per phase;
  on a real pod this is where per-host attribution plugs in.
* **Elastic re-mesh**: ``CheckpointManager.restore(shardings=...)`` reshards
  on load, so a restart under a different device count only needs a new
  mesh + sharding tree (exercised in tests with different CPU device
  counts).
* **Data loading / eval**: ``num_workers > 0`` swaps the prefetch thread
  for shared-memory worker processes (``repro.data.workers``) behind the
  identical ``(index, batch)`` contract; ``evaluator``/``eval_every``
  stream held-out perplexity between chunks, with eval boundaries on the
  same absolute grid (DESIGN.md §5).
"""

from __future__ import annotations

import signal
import time
from collections import deque
from typing import Callable, List, Optional

from repro import obs


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._orig = {}
        for s in signals:
            try:
                self._orig[s] = signal.signal(s, self._on_signal)
            except ValueError:  # non-main thread (tests)
                pass

    def _on_signal(self, signum, frame):
        self.requested = True

    def restore(self):
        for s, h in self._orig.items():
            signal.signal(s, h)


class StepWatchdog:
    """Two-phase straggler monitor.

    * ``start()`` / ``stop(step, n_steps)`` time the **dispatch** phase:
      how long the host spends enqueueing ``n_steps`` worth of work.  Under
      an async backend this is python + transfer overhead, NOT device
      compute — which is why it is tracked separately from
    * ``block(dt, n_steps)``: the **blocked** phase — host time stalled on
      device results (metric fetches at ``log_every``, snapshot syncs,
      blocking saves).  Device-side stragglers surface here.

    Each phase keeps a per-step EMA; a sample slower than
    ``slow_factor×EMA`` is logged with a monotonically-increasing incident
    id.  ``ema`` (dispatch) keeps its pre-split name for callers that only
    track one phase.

    Incident *records* land in ``incident_log``, a ring buffer capped at
    ``max_incidents`` (a pathological run — e.g. one straggling host in a
    large pod — can flag every chunk for days; the count stays exact while
    the records stay bounded, with ``incidents_dropped`` reporting the
    overflow).  ``incidents`` remains the total integer count.  Each
    incident is also emitted to the process-global metric sink
    (``repro.obs``) as a ``watchdog_incident`` record.
    """

    def __init__(self, slow_factor: float = 3.0, ema_alpha: float = 0.1,
                 log: Callable[[str], None] = print,
                 max_incidents: int = 64):
        self.slow_factor = slow_factor
        self.alpha = ema_alpha
        self.ema: Optional[float] = None         # dispatch s/step
        self.block_ema: Optional[float] = None   # blocked s/step
        self._incidents = 0
        self.incident_log: deque = deque(maxlen=max(int(max_incidents), 1))
        self.log = log
        self._t0: Optional[float] = None
        self._step = 0

    @property
    def incidents(self) -> int:
        """Total incident count (exact even after the ring drops records)."""
        return self._incidents

    @property
    def incidents_dropped(self) -> int:
        return self._incidents - len(self.incident_log)

    def _observe(self, phase: str, step: int, per_step: float,
                 ema: Optional[float]) -> float:
        if ema is not None and per_step > self.slow_factor * ema:
            self._incidents += 1
            rec = {"id": self._incidents, "step": step, "phase": phase,
                   "s_per_step": per_step, "ema": ema}
            self.incident_log.append(rec)
            obs.get().emit("watchdog_incident", **rec)
            self.log(f"[watchdog] step {step}: {phase} {per_step:.3f}s/step"
                     f" > {self.slow_factor:.1f}x EMA {ema:.3f}s "
                     f"(incident #{self._incidents})")
        return per_step if ema is None \
            else self.alpha * per_step + (1 - self.alpha) * ema

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int, n_steps: int = 1, record: bool = True) -> float:
        """``record=False`` returns the elapsed time without feeding the
        EMA — used for samples known to be unrepresentative (a chunk
        length's first dispatch includes its XLA compile; letting that
        seed the EMA would mask real stragglers for many chunks)."""
        dt = time.monotonic() - self._t0
        self._step = step
        if record:
            self.ema = self._observe("dispatch", step, dt / max(n_steps, 1),
                                     self.ema)
        return dt

    def block(self, dt: float, n_steps: int = 1, step: Optional[int] = None):
        self.block_ema = self._observe(
            "blocked", self._step if step is None else step,
            dt / max(n_steps, 1), self.block_ema)

    def summary(self) -> dict:
        return {"dispatch_s_per_step": self.ema,
                "blocked_s_per_step": self.block_ema,
                "incidents": self.incidents,
                "incidents_dropped": self.incidents_dropped,
                "incident_log": list(self.incident_log)}


class TrainLoop:
    """Checkpointed, preemption-safe, straggler-monitored loop around a
    train_step ``(params, opt_state, batch) -> (params, opt_state,
    metrics)``.  Used by launch/train.py, the examples, and the step
    benchmark.

    ``pipelined=True`` (default) wraps the step in a jitted
    scan-over-chunk *superstep* with ``donate_argnums=(params,
    opt_state)`` — pass the **un-jitted** step function (a pre-jitted one
    works too; it simply inlines).  The arrays passed to :meth:`run` are
    donated on the first dispatch and must not be reused by the caller
    (their shapes/dtypes stay readable).  ``donate=False`` opts out for
    callers that need the inputs afterwards.

    ``pipelined=False`` reproduces the pre-pipeline loop — one dispatch
    and one blocking ``float(loss)`` per step, synchronous batch fetch, no
    donation — and is what ``benchmarks/run.py step`` measures the
    pipelined loop against.
    """

    def __init__(self, train_step, ckpt, data_source, *,
                 ckpt_every: int = 100, log_every: int = 10,
                 log: Callable[[str], None] = print,
                 pipelined: bool = True, donate: bool = True,
                 max_chunk: int = 16, save_final: bool = False,
                 batch_shardings=None, num_workers: int = 0,
                 evaluator=None, eval_every: int = 0, tap_step=None):
        self.train_step = train_step
        # optional tapped variant (lm.make_train_step(taps=True)): the
        # superstep scan runs it ONLY on the last iteration of each chunk
        # (a scan-body ``lax.cond`` on the step index), so the on-device
        # tap reductions cost 1/chunk of a per-step fusion while still
        # landing exactly on the log_every boundary where flush() fetches
        # them — same single dispatch, no extra launches or host syncs.
        # None -> the superstep graph is identical to the pre-obs loop
        # (the metrics-dir-unset bitwise guarantee).
        self.tap_step = tap_step
        self.ckpt = ckpt
        self.data = data_source
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log
        self.pipelined = pipelined
        self.donate = donate
        self.max_chunk = max(int(max_chunk), 1)
        self.save_final = save_final
        # data loading: 0 = background thread (Prefetcher); N > 0 = N
        # worker PROCESSES (repro.data.workers.ProcessPrefetcher) — same
        # (index, batch) protocol, so the desync check below is identical.
        # Batches are a pure function of the step, so worker count can
        # change across a resume without perturbing the stream.
        self.num_workers = int(num_workers)
        # held-out eval (repro.data.eval.Evaluator): runs between chunks
        # every `eval_every` steps — eval boundaries join the absolute
        # chunk grid, so enabling eval changes chunk partitioning (and
        # hence rounding) deterministically, identically across resumes.
        self.evaluator = evaluator
        self.eval_every = int(eval_every)
        # per-batch NamedSharding dict (the mesh-aware step's input
        # layout): host chunks are device_put straight onto the DP shards
        # — one H2D per device instead of a replicated upload that the
        # first sharding constraint immediately re-slices.
        self.batch_shardings = batch_shardings
        self._chunk_shardings = None  # leading scan axis added lazily
        self.watchdog = StepWatchdog(log=log)
        self.preempt = PreemptionHandler()
        self._superstep = None  # built lazily, reused across run() calls
        self._tap_keys = None   # tap names, recorded at superstep trace
        # Align the chunk grid to log_every when a reasonable divisor
        # exists: uniform chunk lengths mean ONE superstep compilation
        # instead of one per distinct length (log_every=20, max_chunk=16
        # would otherwise produce 16/4/12/8-step chunks, each compiled).
        # log_every boundaries cap chunks regardless, so the divisor only
        # has to be a decent fraction of min(max_chunk, log_every) — not
        # of max_chunk itself — to win; below that (e.g. prime log_every
        # smaller than max_chunk/2) mixed lengths amortize better than a
        # degenerate tiny uniform grid.
        g = self.max_chunk
        if log_every:
            cap = min(g, log_every)
            d = next((d for d in range(cap, 0, -1)
                      if log_every % d == 0), g)
            if d >= max(1, cap // 2):
                g = d
        self._grid = g

    # -- pipelined machinery -----------------------------------------------
    def _place(self, key: str, stacked):
        """Host (k, B, ...) chunk -> device.  With ``batch_shardings`` the
        chunk lands pre-sharded: the per-batch spec gains a replicated
        leading scan axis (every device sees every chunk index, only its
        own batch rows)."""
        import jax
        import jax.numpy as jnp
        if self.batch_shardings is None or key not in self.batch_shardings:
            return jnp.asarray(stacked)
        if self._chunk_shardings is None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            self._chunk_shardings = {
                kk: NamedSharding(sh.mesh, P(None, *sh.spec))
                for kk, sh in self.batch_shardings.items()}
        return jax.device_put(stacked, self._chunk_shardings[key])

    def _build_superstep(self):
        import jax
        train_step = self.train_step
        tap_step = self.tap_step

        if tap_step is None:
            def superstep(params, opt_state, batches):
                def body(carry, batch):
                    p, s = carry
                    p, s, metrics = train_step(p, s, batch)
                    return (p, s), metrics["loss"]

                (params, opt_state), losses = jax.lax.scan(
                    body, (params, opt_state), batches)
                return params, opt_state, losses
        else:
            # Tapped superstep: same scan, but a lax.cond on the step
            # index routes the LAST iteration through the tapped step.
            # Keeping the boundary step inside the scan (vs a second
            # dispatch, or an unrolled final step after a k-1 scan)
            # measured cheapest on the step benchmark — one program, one
            # dispatch, and the tap reductions run once per chunk.  Off-
            # boundary iterations emit structural zeros for the tap ys so
            # both cond branches return identical pytrees.  The tap dict
            # is packed into ONE (T,) f32 vector (key order recorded at
            # trace time) so flush()'s device_get pulls two buffers per
            # chunk, not one per tap — a dict of ~30 scalar transfers
            # measured >1% of segment wall clock on its own.
            import jax.numpy as jnp

            def superstep(params, opt_state, batches):
                k = jax.tree_util.tree_leaves(batches)[0].shape[0]
                first = jax.tree_util.tree_map(lambda v: v[0], batches)
                spec = jax.eval_shape(
                    lambda p, s, b: tap_step(p, s, b)[2]["taps"],
                    params, opt_state, first)
                keys = sorted(spec)
                # trace-time side effect: tap names are static and
                # identical across chunk-length retraces
                self._tap_keys = keys
                zeros = jnp.zeros((len(keys),), jnp.float32)

                def body(carry, xs):
                    i, batch = xs
                    p, s = carry

                    def tapped(p, s):
                        p, s, m = tap_step(p, s, batch)
                        vec = jnp.stack(
                            [m["taps"][key].astype(jnp.float32)
                             for key in keys]) if keys else zeros
                        return p, s, m["loss"], vec

                    def plain(p, s):
                        p, s, m = train_step(p, s, batch)
                        return p, s, m["loss"], zeros

                    p, s, loss, taps = jax.lax.cond(
                        i == k - 1, tapped, plain, p, s)
                    return (p, s), (loss, taps)

                (params, opt_state), (losses, tapmat) = jax.lax.scan(
                    body, (params, opt_state), (jnp.arange(k), batches))
                return params, opt_state, (losses, tapmat[-1])

        kw = {"donate_argnums": (0, 1)} if self.donate else {}
        return jax.jit(superstep, **kw)

    def _chunk_end(self, step: int, num_steps: int) -> int:
        """Next chunk boundary AFTER ``step`` on the absolute grid.

        Boundaries are multiples of ``max_chunk`` / ``log_every`` /
        ``ckpt_every`` plus ``num_steps`` — a pure function of the step
        number, so a resumed run partitions the remaining steps exactly
        like the original run did (scan groupings, and hence float
        reduction order, are reproduced bit-for-bit)."""
        def nxt(every: int) -> int:
            return (step // every + 1) * every

        ends = [num_steps, nxt(self._grid)]
        if self.log_every:
            ends.append(nxt(self.log_every))
        if self.ckpt is not None and self.ckpt_every:
            ends.append(nxt(self.ckpt_every))
        if self.evaluator is not None and self.eval_every:
            ends.append(nxt(self.eval_every))
        return max(min(ends), step + 1)

    def _maybe_eval(self, step: int, params, k: int = 1):
        if self.evaluator is None or not self.eval_every \
                or step % self.eval_every:
            return
        t0 = time.monotonic()
        tel = obs.get()
        with tel.span("eval", step=step):
            r = self.evaluator(params, step)
        self.watchdog.block(time.monotonic() - t0, k)
        tel.emit("eval", step=step, loss=float(r["loss"]),
                 ppl=float(r["ppl"]), n_batches=self.evaluator.n_batches)
        self.log(f"step {step}: eval_loss={r['loss']:.4f} "
                 f"ppl={r['ppl']:.2f} ({self.evaluator.n_batches} batches)")

    def _save(self, step, params, opt_state, *, blocking=False,
              snapshot=False):
        self.ckpt.save(step, {"params": params, "opt": opt_state},
                       blocking=blocking, snapshot=snapshot)

    def _finalize(self, step, params, opt_state, preempted, last_saved):
        """Shared run epilogue: final blocking save (unless this step was
        just checkpointed, or the preempt path already saved it) + join
        the async writer."""
        if self.ckpt is None:
            return
        if self.save_final and not preempted and last_saved != step:
            self._save(step, params, opt_state, blocking=True)
        self.ckpt.wait()

    def run(self, params, opt_state, *, start_step: int = 0,
            num_steps: int = 100):
        if not self.pipelined:
            return self._run_eager(params, opt_state, start_step, num_steps)
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.data.pipeline import Prefetcher, stack_batches

        if self._superstep is None:
            self._superstep = self._build_superstep()

        tel = obs.get()
        losses: List[float] = []
        # device metric chunks pending one host fetch: (base_step, ys)
        # where ys is a (k,) loss vector or, on the tapped path,
        # ((k,) losses, (T,) tap vector sampled at the chunk's last step
        # — names in self._tap_keys, recorded when the superstep traced)
        window: list = []
        nwin = 0

        def flush():
            nonlocal window, nwin
            if not window:
                return
            t0 = time.monotonic()
            with tel.span("block", steps=nwin):
                fetched = jax.device_get([ys for _, ys in window])
            self.watchdog.block(time.monotonic() - t0, nwin)
            emit = getattr(tel.sink, "enabled", True)
            for (base, _), ys in zip(window, fetched):
                tapped = isinstance(ys, tuple)
                lv = np.asarray(ys[0] if tapped else ys)
                losses.extend(float(v) for v in lv)
                if not emit:
                    continue
                for j, lval in enumerate(lv):
                    rec = {"step": base + j + 1, "loss": float(lval)}
                    if tapped and j == len(lv) - 1:
                        rec.update(zip(self._tap_keys,
                                       np.asarray(ys[1], float).tolist()))
                    tel.emit("train_step", **rec)
            window, nwin = [], 0

        step = start_step
        if self.num_workers > 0:
            from repro.data.workers import ProcessPrefetcher
            pf = ProcessPrefetcher(self.data, start_step=step,
                                   depth=2 * self.max_chunk,
                                   num_workers=self.num_workers)
        else:
            pf = Prefetcher(self.data, start_step=step,
                            depth=2 * self.max_chunk)
        preempted = False
        last_saved = None
        compiled_sizes: set = set()   # chunk lengths whose compile is paid
        try:
            while step < num_steps:
                end = self._chunk_end(step, num_steps)
                k = end - step
                batches = []
                with tel.span("prefetch", steps=k):
                    for j in range(k):
                        i, b = next(pf)
                        if i != step + j:   # bit-determinism depends on this
                            raise RuntimeError(
                                f"data stream desync: got batch "
                                f"{i}, want {step + j}")
                        batches.append(b)
                    chunk = {kk: self._place(kk, v)
                             for kk, v in stack_batches(batches).items()}
                self.watchdog.start()
                with tel.span("dispatch", step=step, steps=k):
                    params, opt_state, lchunk = self._superstep(
                        params, opt_state, chunk)
                dt = self.watchdog.stop(step, k,
                                        record=k in compiled_sizes)
                compiled_sizes.add(k)
                window.append((step, lchunk))
                nwin += k
                step = end
                if self.log_every and step % self.log_every == 0:
                    flush()
                    self.log(f"step {step}: loss={losses[-1]:.4f} "
                             f"(dispatch {dt / k * 1e3:.1f}ms/step, blocked "
                             f"{(self.watchdog.block_ema or 0) * 1e3:.1f}"
                             f"ms/step)")
                self._maybe_eval(step, params, k)
                if self.ckpt is not None and self.ckpt_every \
                        and step % self.ckpt_every == 0:
                    t0 = time.monotonic()
                    with tel.span("save", step=step):
                        self._save(step, params, opt_state, snapshot=True)
                    last_saved = step
                    self.watchdog.block(time.monotonic() - t0, k)
                if self.preempt.requested:
                    preempted = True
                    flush()
                    self.log(f"[preempt] checkpoint@{step} and exit")
                    if self.ckpt is not None:
                        self._save(step, params, opt_state, blocking=True)
                    break
        finally:
            pf.close()
        flush()
        self._finalize(step, params, opt_state, preempted, last_saved)
        # fold the watchdog's phase split into the sink (ring-buffered
        # incident records included) so post-hoc analysis needs no stdout
        tel.emit("watchdog_summary", step=step, **self.watchdog.summary())
        return params, opt_state, losses

    # -- pre-pipeline reference loop ---------------------------------------
    def _run_eager(self, params, opt_state, start_step: int, num_steps: int):
        """The pre-pipeline semantics: sync fetch, one dispatch + one
        ``float(loss)`` host sync per step, undonated buffers.  Kept as the
        benchmark baseline and for callers that need per-step host
        control."""
        import jax
        step = start_step
        losses: List[float] = []
        last_saved = None
        while step < num_steps:
            batch = self.data.batch(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self.watchdog.start()
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            self.watchdog.stop(step)
            t0 = time.monotonic()
            loss = float(metrics["loss"])
            self.watchdog.block(time.monotonic() - t0)
            losses.append(loss)
            step += 1
            if self.log_every and step % self.log_every == 0:
                self.log(f"step {step}: loss={loss:.4f}")
            self._maybe_eval(step, params)
            if self.ckpt is not None and self.ckpt_every \
                    and step % self.ckpt_every == 0:
                self._save(step, params, opt_state)
                last_saved = step
            if self.preempt.requested:
                self.log(f"[preempt] checkpoint@{step} and exit")
                if self.ckpt is not None:
                    self._save(step, params, opt_state, blocking=True)
                break
        self._finalize(step, params, opt_state, self.preempt.requested,
                       last_saved)
        return params, opt_state, losses
