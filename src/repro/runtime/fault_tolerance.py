"""Fault-tolerant training runtime: preemption handling, auto-resume,
step watchdog / straggler detection, and an elastic re-mesh hook.

Designed for the 1000+-node posture (DESIGN.md §4):

* **Preemption**: SIGTERM/SIGINT set a flag; the train loop checkpoints
  synchronously and exits 0 (the scheduler restarts the job, which
  auto-resumes from the latest committed step).
* **Watchdog**: an EMA of step time; steps slower than ``k×EMA`` are logged
  with a monotonically-increasing incident id — on a real pod this is where
  per-host attribution (via ``jax.process_index`` heartbeats) plugs in.
  Input-side stragglers are already decoupled by the data prefetcher.
* **Elastic re-mesh**: ``CheckpointManager.restore(shardings=...)`` reshards
  on load, so a restart under a different device count only needs a new
  mesh + sharding tree (exercised in tests with different CPU device
  counts).
"""

from __future__ import annotations

import signal
import time
from typing import Callable, Optional


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._orig = {}
        for s in signals:
            try:
                self._orig[s] = signal.signal(s, self._on_signal)
            except ValueError:  # non-main thread (tests)
                pass

    def _on_signal(self, signum, frame):
        self.requested = True

    def restore(self):
        for s, h in self._orig.items():
            signal.signal(s, h)


class StepWatchdog:
    def __init__(self, slow_factor: float = 3.0, ema_alpha: float = 0.1,
                 log: Callable[[str], None] = print):
        self.slow_factor = slow_factor
        self.alpha = ema_alpha
        self.ema: Optional[float] = None
        self.incidents = 0
        self.log = log
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        dt = time.monotonic() - self._t0
        if self.ema is None:
            self.ema = dt
        elif dt > self.slow_factor * self.ema:
            self.incidents += 1
            self.log(f"[watchdog] step {step}: {dt:.3f}s > "
                     f"{self.slow_factor:.1f}x EMA {self.ema:.3f}s "
                     f"(incident #{self.incidents})")
        self.ema = self.alpha * dt + (1 - self.alpha) * (self.ema or dt)
        return dt


class TrainLoop:
    """Checkpointed, preemption-safe, straggler-monitored loop around a
    compiled train_step.  Used by launch/train.py and the examples."""

    def __init__(self, train_step, ckpt, data_source, *,
                 ckpt_every: int = 100, log_every: int = 10,
                 log: Callable[[str], None] = print):
        self.train_step = train_step
        self.ckpt = ckpt
        self.data = data_source
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log
        self.watchdog = StepWatchdog(log=log)
        self.preempt = PreemptionHandler()

    def run(self, params, opt_state, *, start_step: int = 0,
            num_steps: int = 100):
        import jax
        step = start_step
        losses = []
        while step < num_steps:
            batch = self.data.batch(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self.watchdog.start()
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            loss = float(metrics["loss"])
            dt = self.watchdog.stop(step)
            losses.append(loss)
            step += 1
            if step % self.log_every == 0:
                self.log(f"step {step}: loss={loss:.4f} ({dt*1e3:.0f}ms)")
            if step % self.ckpt_every == 0 and self.ckpt is not None:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
            if self.preempt.requested:
                self.log(f"[preempt] checkpoint@{step} and exit")
                if self.ckpt is not None:
                    self.ckpt.save(step, {"params": params, "opt": opt_state},
                                   blocking=True)
                break
        if self.ckpt is not None:
            self.ckpt.wait()
        return params, opt_state, losses
