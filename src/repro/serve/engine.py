"""Continuous-batching scheduler over the slot-paged KV cache
(DESIGN.md §9).

One :class:`Engine` owns ``num_slots`` request slots, a shared page arena
per attention layer (:mod:`repro.serve.kv`), and exactly two compiled
functions — reused for the whole lifetime of the engine:

* ``chunk_prefill``: pages in ONE waiting request's next
  ``prefill_chunk`` prompt tokens (fixed ``(1, C)`` shape; the final
  short chunk is padded — padded positions land beyond the slot's length
  and are never valid before decode overwrites them);
* ``decode``: one greedy token for EVERY slot (fixed
  ``(num_slots, 1)`` shape; non-decoding slots carry the trash page
  table and a zero length, so their scatters land in page 0 and their
  garbage logits are simply not read).

Every scheduler tick interleaves both: admit arrived requests into free
slots (page allocation is a free-list pop), run one prefill chunk if any
slot is mid-prompt, then one decode step if any slot is generating.
Requests therefore join and leave the running batch *between decode
steps* — the continuous-batching property — instead of the static-wave
discipline (``static=True``: admit only when all slots are free, decode
only once every admitted prompt is fully paged in) that the serve
benchmark uses as its baseline.

Both compiled steps are jitted with ``donate_argnums=(1,)``: the page
pools are the only mutated state and XLA aliases them in place, so the
persistent footprint is one arena regardless of how long the engine
runs.  The engine rebinds ``self.pools`` after every call — donated
buffers must never be reused.

Greedy decoding only: the engine exists to exercise and measure the
serving *runtime* — scheduling, page accounting, cache quantization —
not sampling strategies.  A request retires when it hits its ``max_gen``
bound, emits ``EngineConfig.eos_id``, or its generation ends with any of
``EngineConfig.stop_seqs`` — retirement frees the slot's pages
immediately, so a queued request can be admitted the very next tick.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import lm
from repro.serve import kv as kv_lib

FREE, PREFILL, DECODE = 0, 1, 2


@dataclass
class Request:
    """One serving request.  ``arrival`` is seconds after ``run()`` starts
    (0 = backlogged); the engine fills the telemetry fields."""
    rid: int
    prompt: Sequence[int]
    max_gen: int
    arrival: float = 0.0
    generated: List[int] = field(default_factory=list)
    t_admit: float = -1.0
    t_first: float = -1.0   # first generated token (end of prefill)
    t_done: float = -1.0


@dataclass
class EngineConfig:
    num_slots: int = 4
    page_size: int = 16
    max_ctx: int = 256          # per-request prompt + generation bound
    prefill_chunk: int = 32
    kv_quant: Optional[str] = None      # None | "int8"
    num_pages: Optional[int] = None     # default: every slot can fill up
    eos_id: Optional[int] = None        # retire the slot on this token
    stop_seqs: Sequence[Sequence[int]] = ()   # ...or on any of these tails

    @property
    def max_pages(self) -> int:
        return -(-self.max_ctx // self.page_size)

    def resolved_num_pages(self) -> int:
        return self.num_pages if self.num_pages is not None \
            else 1 + self.num_slots * self.max_pages


class Engine:
    def __init__(self, cfg, params, ecfg: Optional[EngineConfig] = None,
                 ctx=None):
        ecfg = ecfg or EngineConfig()
        if getattr(cfg, "arch_class", "decoder") == "encdec":
            raise NotImplementedError(
                "Engine serves decoder-only archs; enc-dec decoding lives "
                "in repro.models.encdec.decode_stack (see tests/"
                "test_models.py::test_encdec_decode_matches_teacher_forcing)")
        bad = [k for k in cfg.pattern if k.split("+")[0] != "attn"]
        if bad or (cfg.window or 0):
            raise NotImplementedError(
                f"paged serving covers full-attention decoder stacks; "
                f"pattern {cfg.pattern} window {cfg.window} has no "
                f"page-table layout (sliding windows ring-buffer, "
                f"recurrent mixers keep O(1) state)")
        if cfg.mrope_sections:
            raise NotImplementedError("paged serving does not thread "
                                      "multimodal rope position trees")
        np_ = ecfg.resolved_num_pages()
        if np_ < 1 + ecfg.max_pages:
            raise ValueError(
                f"num_pages={np_} cannot hold even one full request "
                f"({ecfg.max_pages} pages) plus the trash page")
        self.cfg, self.params, self.ecfg, self.ctx = cfg, params, ecfg, ctx
        self.num_pages = np_
        self.pools = lm.init_paged_caches(cfg, np_, ecfg.page_size,
                                          kv_quant=ecfg.kv_quant)
        # argmax is fused INTO the compiled steps: returning (V,)-wide
        # logits for an eager argmax costs one extra host dispatch per
        # tick, which at serving batch sizes is scheduler-dominating
        chunk = lm.make_chunk_prefill_step(cfg, ctx=ctx)
        decode = lm.make_paged_decode_step(cfg, ctx=ctx)

        def chunk_step(params, pools, pt, filled, tokens):
            logits, pools = chunk(params, pools, pt, filled, tokens)
            return jnp.argmax(logits[0], axis=-1), pools    # (C,) greedy

        def decode_step(params, pools, pt, lens, tokens):
            logits, pools = decode(params, pools, pt, lens, tokens)
            return jnp.argmax(logits, axis=-1), pools       # (num_slots,)

        self._chunk_step = jax.jit(chunk_step, donate_argnums=(1,))
        self._decode_step = jax.jit(decode_step, donate_argnums=(1,))
        self._tel = obs.get()   # re-resolved per run(); see there
        self.reset()

    # -- bookkeeping -------------------------------------------------------
    def reset(self):
        """Clear scheduler state between runs.  The pools are NOT zeroed:
        stale entries sit beyond every slot's ``kv_valid`` horizon, so
        correctness never depends on arena contents."""
        e = self.ecfg
        self.page_table = np.zeros((e.num_slots, e.max_pages), np.int32)
        self.lens = np.zeros((e.num_slots,), np.int32)
        self.free_pages = list(range(self.num_pages - 1, 0, -1))  # pop -> 1,2,..
        self.slots = [{"state": FREE, "req": None, "filled": 0,
                       "pages": [], "last": 0} for _ in range(e.num_slots)]

    def kv_bytes(self) -> int:
        return kv_lib.pool_bytes(self.pools)

    @classmethod
    def from_checkpoint(cls, cfg, ckpt_dir: str,
                        ecfg: Optional[EngineConfig] = None,
                        step: Optional[int] = None, ctx=None,
                        merge_lora: Optional[bool] = None,
                        lora_rank: int = 8,
                        lora_alpha: float = 16.0) -> "Engine":
        """Build an engine straight from a training checkpoint directory,
        loading only the params leaves (the optimizer state never touches
        host memory — ``CheckpointManager.restore_params``).

        Fine-tuned checkpoints hold a ``{"base", "lora"}`` tree instead of
        plain params; the engine's forward knows nothing about adapters,
        so they are merged into the base weights at load
        (:func:`repro.models.lora.merge`).  ``merge_lora=None``
        auto-detects from the checkpoint's run metadata (``--finetune
        lora`` runs stamp rank/alpha there); pass ``True`` with
        ``lora_rank``/``lora_alpha`` for checkpoints written without it."""
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(ckpt_dir)
        ft = mgr.saved_run(step).get("finetune") or {}
        if merge_lora is None:
            merge_lora = ft.get("mode") == "lora"
        if merge_lora:
            from repro.models import lora
            rank = int(ft.get("rank", lora_rank))
            alpha = float(ft.get("alpha", lora_alpha))
            like = jax.eval_shape(
                lambda p: lora.inject(p, rank, jax.random.key(0)),
                lm.abstract_params(cfg))
            tree, _ = mgr.restore_params(step, like, ctx=ctx)
            params = lora.merge(tree, alpha, rank)
        else:
            params, _ = mgr.restore_params(
                step, lm.abstract_params(cfg), ctx=ctx)
        return cls(cfg, params, ecfg, ctx=ctx)

    def warmup(self):
        """Trigger both compiles against the trash page so timed runs
        measure steady-state scheduling, not tracing."""
        e = self.ecfg
        _, self.pools = self._chunk_step(
            self.params, self.pools, jnp.zeros((1, e.max_pages), jnp.int32),
            jnp.zeros((1,), jnp.int32),
            jnp.zeros((1, e.prefill_chunk), jnp.int32))
        _, self.pools = self._decode_step(
            self.params, self.pools,
            jnp.zeros((e.num_slots, e.max_pages), jnp.int32),
            jnp.zeros((e.num_slots,), jnp.int32),
            jnp.zeros((e.num_slots, 1), jnp.int32))

    # -- scheduling --------------------------------------------------------
    def _admit_one(self, req: Request, slot: int, now: float) -> bool:
        plen, cap = len(req.prompt), self.ecfg.max_ctx
        if plen + req.max_gen > cap:
            raise ValueError(f"request {req.rid}: prompt {plen} + gen "
                             f"{req.max_gen} exceeds max_ctx {cap}")
        need = -(-(plen + req.max_gen) // self.ecfg.page_size)
        if len(self.free_pages) < need:
            return False
        pages = [self.free_pages.pop() for _ in range(need)]
        self.page_table[slot, :] = kv_lib.TRASH_PAGE
        self.page_table[slot, :need] = pages
        self.lens[slot] = 0
        s = self.slots[slot]
        s.update(state=PREFILL, req=req, filled=0, pages=pages, last=0)
        req.t_admit = now
        return True

    def _admit(self, pending: deque, now: float, static: bool):
        if static and any(s["state"] != FREE for s in self.slots):
            return  # static waves: the whole batch drains before refill
        for slot, s in enumerate(self.slots):
            if not pending or pending[0].arrival > now:
                break
            if s["state"] != FREE:
                continue
            if not self._admit_one(pending[0], slot, now):
                break   # page pressure: keep FIFO order, wait for retires
            pending.popleft()

    def _finished(self, req: Request) -> bool:
        """max_gen bound, EOS token, or a stop-sequence tail — checked
        after every appended token (prefill's first token included), so a
        stopped slot frees its pages before the next admit pass."""
        if len(req.generated) >= req.max_gen:
            return True
        e = self.ecfg
        if e.eos_id is not None and req.generated \
                and req.generated[-1] == e.eos_id:
            return True
        return any(stop and len(req.generated) >= len(stop)
                   and req.generated[-len(stop):] == list(stop)
                   for stop in e.stop_seqs)

    def _retire(self, slot: int, now: float):
        s = self.slots[slot]
        self.free_pages.extend(sorted(s["pages"], reverse=True))
        self.page_table[slot, :] = kv_lib.TRASH_PAGE
        self.lens[slot] = 0
        req = s["req"]
        req.t_done = now
        # per-request record emitted AT retirement, not at end of run():
        # a killed run leaves one usable JSONL line per completed request
        # (the sink flushes per record), instead of losing everything to
        # the end-of-run percentile pass.
        self._tel.emit(
            "serve_request", rid=req.rid, slot=slot,
            prompt_tokens=len(req.prompt), gen_tokens=len(req.generated),
            arrival_s=req.arrival, admit_s=req.t_admit,
            first_token_s=req.t_first, done_s=req.t_done,
            ttft_s=req.t_first - req.t_admit,
            latency_s=req.t_done - req.arrival)
        s.update(state=FREE, req=None, filled=0, pages=[], last=0)

    def _prefill_tick(self, now) -> bool:
        slot = next((i for i, s in enumerate(self.slots)
                     if s["state"] == PREFILL), None)
        if slot is None:
            return False
        s = self.slots[slot]
        req, C = s["req"], self.ecfg.prefill_chunk
        plen = len(req.prompt)
        chunk = list(req.prompt[s["filled"]:s["filled"] + C])
        real = len(chunk)
        tokens = jnp.asarray([chunk + [0] * (C - real)], jnp.int32)
        with self._tel.span("prefill", cat="serve", slot=slot,
                            rid=req.rid, tokens=real):
            greedy, self.pools = self._chunk_step(
                self.params, self.pools,
                jnp.asarray(self.page_table[slot:slot + 1]),
                jnp.asarray([s["filled"]], jnp.int32), tokens)
        s["filled"] += real
        if s["filled"] >= plen:
            # prompt fully paged in: its final position's greedy token is
            # in THIS chunk (possibly mid-chunk when the tail was padded)
            g0 = int(greedy[plen - 1 - (s["filled"] - real)])
            req.generated.append(g0)
            req.t_first = now()
            self.lens[slot] = plen
            if self._finished(req):
                self._retire(slot, now())
            else:
                s.update(state=DECODE, last=g0)
        return True

    def _decode_tick(self, now, static: bool) -> bool:
        active = [i for i, s in enumerate(self.slots)
                  if s["state"] == DECODE]
        if not active:
            return False
        if static and any(s["state"] == PREFILL for s in self.slots):
            return False  # static baseline: decode starts when the wave is in
        e = self.ecfg
        tokens = np.zeros((e.num_slots, 1), np.int32)
        pt = np.zeros_like(self.page_table)     # non-decode rows -> trash
        ln = np.zeros_like(self.lens)
        for i in active:
            tokens[i, 0] = self.slots[i]["last"]
            pt[i] = self.page_table[i]
            ln[i] = self.lens[i]
        with self._tel.span("decode", cat="serve", active=len(active)):
            greedy, self.pools = self._decode_step(
                self.params, self.pools, jnp.asarray(pt), jnp.asarray(ln),
                jnp.asarray(tokens))
        nxt = np.asarray(greedy)
        for i in active:
            s = self.slots[i]
            self.lens[i] += 1
            tok = int(nxt[i])
            s["req"].generated.append(tok)
            s["last"] = tok
            if self._finished(s["req"]):
                self._retire(i, now())
        return True

    def run(self, requests: Sequence[Request], static: bool = False) -> dict:
        """Serve ``requests`` to completion under open-loop arrivals
        (each request joins the queue at its ``arrival`` offset, whether
        or not the engine is keeping up).  Returns aggregate stats; the
        per-request telemetry lands on the Request objects."""
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        # late-bound: the launcher configures the global Telemetry after
        # engine construction; ticks and _retire read self._tel
        tel = self._tel = obs.get()
        t0 = time.monotonic()
        now = lambda: time.monotonic() - t0
        arena = max(self.num_pages - 1, 1)   # page 0 is the trash page
        while pending or any(s["state"] != FREE for s in self.slots):
            self._admit(pending, now(), static)
            busy = self._prefill_tick(now)
            busy = self._decode_tick(now, static) or busy
            if busy and tel.tracer is not None:
                tel.counter(
                    "sched", cat="serve",
                    queue_depth=sum(r.arrival <= now() for r in pending),
                    slots_busy=sum(s["state"] != FREE for s in self.slots),
                    page_util=1.0 - len(self.free_pages) / arena)
            if not busy and pending:
                time.sleep(max(0.0, min(pending[0].arrival - now(), 0.02)))
        makespan = now()
        lat = sorted(r.t_done - r.arrival for r in requests)
        gen = sum(len(r.generated) for r in requests)
        pct = lambda p: lat[min(len(lat) - 1,
                                int(p / 100.0 * len(lat)))] if lat else 0.0
        stats = {"requests": len(requests),
                 "generated_tokens": gen,
                 "prompt_tokens": sum(len(r.prompt) for r in requests),
                 "makespan_s": makespan,
                 "requests_per_sec": len(requests) / makespan,
                 "tokens_per_sec": gen / makespan,
                 "p50_s": pct(50), "p99_s": pct(99)}
        tel.emit("serve_run", static=static, **stats)
        return stats
