"""Slot-paged KV-cache pools (DESIGN.md §9).

A serving KV cache is ONE preallocated arena per attention layer — a pool
of ``num_pages`` page-granular blocks of ``page_size`` token entries —
shared by every in-flight request.  Each request (slot) owns a set of
pages named by its *page table* row; logical cache position ``t`` of a
slot lives at ``(page_table[slot, t // page_size], t % page_size)``.
Nothing is ever resized or compacted: admitting a request is a free-list
pop, retiring one is a push, and requests of wildly different lengths
never pad each other.

Two pool encodings, chosen at engine construction:

* ``None`` (default) — a plain ``(num_pages, page_size, KV, hd)`` array
  in the model compute dtype.
* ``"int8"`` — ``{"q": int8 (num_pages, page_size, KV, hd),
  "scale": f32 (num_pages, page_size, KV)}``: each written entry's
  per-head vector is quantized against its own absmax through
  :func:`repro.optim.codec.blocked_quant` with ``block=head_dim`` and
  round-to-nearest (entries are encoded exactly once, so the stochastic
  stream the optimizer substrate needs would only add noise here).
  ~4× less persistent KV memory per token.

Page 0 is reserved as the TRASH page: free slots' page-table rows point
at it, so the fixed-shape decode step can scatter a token for *every*
slot each tick — inactive slots land in trash (never read: their
``kv_valid`` mask covers nothing real) instead of needing a ragged
dispatch.  Reads gather a slot's pages into a transient contiguous
``(B, max_pages·page_size, KV, hd)`` view; on CPU/XLA this is a copy the
attention einsum consumes immediately, while the *persistent* footprint
stays the shared arena.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.optim import codec

TRASH_PAGE = 0


def is_quantized(pool) -> bool:
    return isinstance(pool, dict)


def page_size(pool) -> int:
    return (pool["q"] if is_quantized(pool) else pool).shape[1]


def capacity(pool, page_table: jax.Array) -> int:
    """Tokens addressable through one page-table row: max_pages · page."""
    return int(page_table.shape[-1]) * page_size(pool)


def quant_entries(x: jax.Array):
    """``(..., KV, hd) -> (q int8 same shape, scale f32 (..., KV))``: one
    absmax block per written head vector, via the codec's blocked
    primitive (``block = head_dim``, round-to-nearest)."""
    q, scale = codec.blocked_quant(x, jnp.uint32(0), block=int(x.shape[-1]),
                                   rounding="nearest")
    return q, scale.reshape(x.shape[:-1])


def write(pool, page: jax.Array, off: jax.Array, val: jax.Array):
    """Scatter token entries into the pool.

    ``val`` is ``(N, KV, hd)`` new K or V entries; ``page``/``off`` are
    ``(N,)`` destinations.  Distinct live destinations by construction
    (each slot owns its pages); duplicate destinations only occur on the
    trash page, where any write order is fine.
    """
    if is_quantized(pool):
        q, scale = quant_entries(val)
        return {"q": pool["q"].at[page, off].set(q),
                "scale": pool["scale"].at[page, off].set(scale)}
    return pool.at[page, off].set(val.astype(pool.dtype))


def gather(pool, page_table: jax.Array, dtype) -> jax.Array:
    """Materialize page-table rows as a contiguous transient cache view:
    ``(B, max_pages) -> (B, max_pages·page_size, KV, hd)`` in ``dtype``
    (int8 pools dequantize on the way out)."""
    if is_quantized(pool):
        q = pool["q"][page_table]                 # (B, MP, P, KV, hd)
        s = pool["scale"][page_table]             # (B, MP, P, KV)
        x = (q.astype(jnp.float32) * s[..., None]).astype(dtype)
    else:
        x = pool[page_table].astype(dtype)
    B, MP, P = x.shape[0], x.shape[1], x.shape[2]
    return x.reshape(B, MP * P, *x.shape[3:])


def token_dest(page_table: jax.Array, pos: jax.Array, page: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Per-slot decode destination: slot ``b``'s next entry goes to
    ``(page_table[b, pos[b] // page], pos[b] % page)``."""
    B = page_table.shape[0]
    pg = page_table[jnp.arange(B), pos // page]
    return pg, pos % page


def chunk_dest(pt_row: jax.Array, start: jax.Array, n: int, page: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Prefill-chunk destinations: positions ``start .. start+n-1`` of the
    single slot whose page-table row is ``pt_row`` ``(max_pages,)``."""
    positions = start + jnp.arange(n)
    return pt_row[positions // page], positions % page


def pool_bytes(pools) -> int:
    """Persistent arena bytes of a paged-cache tree (the number the int8
    option shrinks ~4×)."""
    return sum(l.size * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(pools))
