"""Serving runtime: continuous (in-flight) batching over a slot-paged,
optionally int8-quantized KV cache (DESIGN.md §9).

``repro.serve.kv`` holds the paged-pool substrate (imported by the model
attention layer for its paged decode path); ``repro.serve.engine`` holds
the scheduler.  The engine import is lazy so ``models → serve.kv`` never
cycles back through ``engine → models``.
"""

__all__ = ["kv", "Engine", "Request", "EngineConfig"]

import importlib


def __getattr__(name):
    # importlib.import_module, not ``from repro.serve import x``: the
    # from-import re-enters this __getattr__ and recurses.
    if name in ("Engine", "Request", "EngineConfig"):
        return getattr(importlib.import_module("repro.serve.engine"), name)
    if name == "kv":
        return importlib.import_module("repro.serve.kv")
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
