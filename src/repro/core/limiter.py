"""Fira's Norm-growth Limiter (NL), adopted by the paper (§III-B, Fig. 3).

    if ||G̃_t||_F / ||G̃_{t-1}||_F > γ:   G̃_t ← G̃_t / ||G̃_t||_F · γ · ||G̃_{t-1}||_F

Stateless helper: caller threads ``prev_norm`` (one f32 scalar per tensor).
``prev_norm == 0`` (first step) disables limiting for that step.  A
zero-norm *update* (e.g. a fully-masked LoRA adapter step or an all-zero
gradient) keeps the previous norm: returning 0 would wipe the limiter
history and disable limiting on the next real step.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

DEFAULT_GAMMA = 1.01


def limit(update: jax.Array, prev_norm: jax.Array, gamma: float = DEFAULT_GAMMA
          ) -> Tuple[jax.Array, jax.Array]:
    """Returns ``(limited_update, new_prev_norm)``."""
    norm = jnp.linalg.norm(update.astype(jnp.float32))
    safe_prev = jnp.where(prev_norm > 0, prev_norm, norm)
    scale = jnp.where(
        norm > gamma * safe_prev,
        gamma * safe_prev / jnp.maximum(norm, 1e-30),
        1.0,
    )
    limited = update * scale.astype(update.dtype)
    new_prev = jnp.where(norm > 0, norm * scale, prev_norm)
    return limited, new_prev.astype(jnp.float32)


def clip_flags(prev_norm: jax.Array, new_norm: jax.Array,
               gamma: float = DEFAULT_GAMMA) -> jax.Array:
    """Did :func:`limit` clip, reconstructed from the norms it threads?

    When a step clips, ``new_prev = norm · (γ·prev/norm) = γ·prev`` up to
    one f32 rounding of the multiply chain; unclipped steps land at
    ``norm ≤ γ·prev`` strictly *below* that product except exactly at the
    boundary (where no scaling happens and the flag is a don't-care).  So
    ``new ≥ γ·prev·(1−2⁻²⁰)`` with ``prev > 0`` detects the clip without
    storing a separate flag — this is the observability tap's detector
    (DESIGN.md §12), reading the fused kernel's norm-pass output instead
    of adding state.  Elementwise over stacked ``(L,)`` norm vectors.
    """
    margin = jnp.float32(1.0 - 2.0 ** -20)
    return (prev_norm > 0) & (new_norm >= gamma * prev_norm * margin)
