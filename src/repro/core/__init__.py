# The paper's primary contribution: GWT — wavelet-domain optimizer-state
# compression (Algorithm 1) + the Haar transform substrate it builds on.
from repro.core.haar import (haar_forward, haar_inverse, haar_forward_packed,
                             haar_inverse_packed, haar_matrix, lowpass,
                             pack, unpack, detail_scale_upsample)
from repro.core.gwt import gwt, state_memory_bytes
from repro.core.limiter import limit

__all__ = ["haar_forward", "haar_inverse", "haar_forward_packed",
           "haar_inverse_packed", "haar_matrix", "lowpass", "pack", "unpack",
           "detail_scale_upsample", "gwt", "state_memory_bytes", "limit"]
