"""Multi-level discrete Haar wavelet transform (DHT) — the paper's Eq. (2)/(3).

Two implementations:
  * ``haar_forward`` / ``haar_inverse`` — fast butterfly (O(m·n) adds, no
    matmul), the production path.
  * ``haar_matrix`` — the explicit orthonormal matrix ``H`` of Eq. (3)
    (and its level-l composition), used as the validation oracle and in
    property tests (``H Hᵀ = I``).

Layout convention (packed form): applying level ``l`` to the last axis of
``g`` of width ``n`` yields ``[A_l | D_l | D_{l-1} | ... | D_1]`` where
``A_l`` has width ``n/2^l`` and band ``D_k`` has width ``n/2^k``.  The packed
array has the same shape as ``g`` (the DHT is a bijection), matching the
paper's "no extra information" property.
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INV_SQRT2 = 0.7071067811865476


def _check(n: int, level: int) -> None:
    if level < 0:
        raise ValueError(f"level must be >= 0, got {level}")
    if n % (1 << level) != 0:
        raise ValueError(f"axis length {n} not divisible by 2^{level}")


def haar_forward(g: jax.Array, level: int) -> Tuple[jax.Array, List[jax.Array]]:
    """Level-``level`` DHT along the last axis.

    Returns ``(A_l, [D_l, D_{l-1}, ..., D_1])``.  ``level == 0`` returns
    ``(g, [])`` (identity — GWT degenerates to the host optimizer).
    """
    _check(g.shape[-1], level)
    a = g
    details: List[jax.Array] = []
    for _ in range(level):
        x = a.reshape(*a.shape[:-1], a.shape[-1] // 2, 2)
        even, odd = x[..., 0], x[..., 1]
        a = (even + odd) * INV_SQRT2
        details.append((even - odd) * INV_SQRT2)
    details.reverse()  # [D_l, ..., D_1]
    return a, details


def haar_approx(g: jax.Array, level: int) -> jax.Array:
    """Approx band ``A_l`` only — the averaging chain of
    :func:`haar_forward` without materializing the detail bands.

    Op-for-op the same computation as ``haar_forward``'s ``a`` path, so
    the result is bitwise equal to ``haar_forward(g, level)[0]`` at half
    the per-level work.  Used by the observability taps (DESIGN.md §12),
    which recover the detail energy via Parseval
    (``ssq(D*) = ssq(g) - ssq(A_l)`` — the DHT is orthonormal) instead
    of computing the bands.
    """
    _check(g.shape[-1], level)
    a = g
    for _ in range(level):
        x = a.reshape(*a.shape[:-1], a.shape[-1] // 2, 2)
        a = (x[..., 0] + x[..., 1]) * INV_SQRT2
    return a


def haar_inverse(a: jax.Array, details: Sequence[jax.Array]) -> jax.Array:
    """Inverse of :func:`haar_forward` (paper Eq. (1))."""
    x = a
    for d in details:  # D_l first: coarsest band reconstructs first
        even = (x + d) * INV_SQRT2
        odd = (x - d) * INV_SQRT2
        x = jnp.stack([even, odd], axis=-1).reshape(*x.shape[:-1], x.shape[-1] * 2)
    return x


def pack(a: jax.Array, details: Sequence[jax.Array]) -> jax.Array:
    """``(A_l, [D_l..D_1]) -> [A_l | D_l | ... | D_1]`` (same total width)."""
    return jnp.concatenate([a, *details], axis=-1)


def unpack(packed: jax.Array, level: int) -> Tuple[jax.Array, List[jax.Array]]:
    n = packed.shape[-1]
    _check(n, level)
    widths = [n >> level] + [n >> k for k in range(level, 0, -1)]
    offs = np.cumsum([0] + widths)
    parts = [packed[..., offs[i]:offs[i + 1]] for i in range(len(widths))]
    return parts[0], parts[1:]


def haar_forward_packed(g: jax.Array, level: int) -> jax.Array:
    return pack(*haar_forward(g, level))


def haar_inverse_packed(packed: jax.Array, level: int) -> jax.Array:
    return haar_inverse(*unpack(packed, level))


@functools.lru_cache(maxsize=64)
def _haar_matrix_np(n: int, level: int) -> np.ndarray:
    """Level-``level`` orthonormal DHT matrix ``H`` with ``G @ H = packed``.

    Level-1 is exactly the paper's Eq. (3); higher levels compose a level-1
    transform on the approximation half.
    """
    _check(n, level)
    h = np.eye(n)
    width = n
    for _ in range(level):
        h1 = np.zeros((width, width))
        half = width // 2
        for i in range(half):
            h1[2 * i, i] = INV_SQRT2        # approx
            h1[2 * i + 1, i] = INV_SQRT2
            h1[2 * i, half + i] = INV_SQRT2  # detail
            h1[2 * i + 1, half + i] = -INV_SQRT2
        step = np.eye(n)
        step[:width, :width] = h1
        # after one level the detail bands already emitted sit to the right
        # and must not be touched again; shift: new packed layout is
        # [A | D_new | D_old...], and h1 maps [A_prev] -> [A | D_new].
        h = h @ step
        width //= 2
    return h


def haar_matrix(n: int, level: int, dtype=jnp.float32) -> jax.Array:
    return jnp.asarray(_haar_matrix_np(n, level), dtype=dtype)


# ---------------------------------------------------------------------------
# Haar low-pass operator P_l of §III-C (Theorem 1): block-mean per 2^l cols.
# ---------------------------------------------------------------------------

def lowpass(g: jax.Array, level: int) -> jax.Array:
    """``P_l(G)``: replace each block of ``2^l`` columns by the block mean."""
    n = g.shape[-1]
    _check(n, level)
    b = 1 << level
    blocks = g.reshape(*g.shape[:-1], n // b, b)
    mean = blocks.mean(axis=-1, keepdims=True)
    return jnp.broadcast_to(mean, blocks.shape).reshape(g.shape)


# ---------------------------------------------------------------------------
# Daubechies-4 (db2) — beyond-paper wavelet option.  The paper uses Haar
# "as the default filter"; db2's longer support captures smoother gradient
# structure.  Periodic (circular) boundary keeps the transform orthonormal
# on ℝ^n (n divisible by 2^l), so Parseval/reconstruction invariants carry
# over and the GWT memory accounting is unchanged.
# ---------------------------------------------------------------------------

_SQRT3 = 1.7320508075688772
# Python floats, not numpy scalars: weak-typed taps let the transform run
# in the input dtype (a numpy float64 scalar would promote bf16 -> f32).
_DB2_LO = tuple(float(c / (4 * np.sqrt(2))) for c in
                (1 + _SQRT3, 3 + _SQRT3, 3 - _SQRT3, 1 - _SQRT3))
_DB2_HI = (_DB2_LO[3], -_DB2_LO[2], _DB2_LO[1], -_DB2_LO[0])


def _db2_level_fwd(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One db2 analysis level along the last axis (periodic)."""
    n = x.shape[-1]
    xr = jnp.concatenate([x, x[..., :3]], axis=-1)  # circular pad (4 taps)
    windows = jnp.stack([xr[..., i:n + i] for i in range(4)], axis=-1)
    even = windows[..., ::2, :]                     # (..., n/2, 4)
    lo = sum(_DB2_LO[i] * even[..., i] for i in range(4))
    hi = sum(_DB2_HI[i] * even[..., i] for i in range(4))
    return lo, hi


def _db2_level_inv(lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Inverse via the transposed (orthonormal) synthesis operator."""
    n2 = lo.shape[-1]
    n = 2 * n2
    out = jnp.zeros(lo.shape[:-1] + (n + 2,), jnp.result_type(lo, hi))
    for i in range(4):
        contrib = lo * _DB2_LO[i] + hi * _DB2_HI[i]
        out = out.at[..., i:i + n:2].add(contrib)
    # fold the circular tail back
    folded = out[..., :n].at[..., :2].add(out[..., n:n + 2])
    return folded


def db2_forward(g: jax.Array, level: int):
    """Like :func:`haar_forward`, db2 preserves the input dtype: a bf16
    ``state_dtype`` host must see the same moment/band dtypes under either
    wavelet."""
    _check(g.shape[-1], level)
    a = g
    details: List[jax.Array] = []
    for _ in range(level):
        a, d = _db2_level_fwd(a)
        details.append(d)
    details.reverse()
    return a, details


def db2_inverse(a: jax.Array, details: Sequence[jax.Array]) -> jax.Array:
    x = a
    for d in details:
        x = _db2_level_inv(x, d)
    return x


def detail_scale_upsample(scale_a: jax.Array, level: int, band_level: int) -> jax.Array:
    """Upsample a per-``A_l``-coefficient scale to band ``D_k`` resolution.

    ``A_l`` coefficient ``j`` covers original columns ``[j·2^l, (j+1)·2^l)``;
    ``D_k`` coefficient ``i`` covers ``[i·2^k, (i+1)·2^k)``.  The unique
    block-consistent extension of the paper's 1-level rule repeats each
    ``A``-scale ``2^{l-k}`` times.
    """
    if scale_a.ndim and scale_a.shape[-1] == 1:
        return scale_a  # already broadcastable (e.g. Adam-mini per-row scale)
    reps = 1 << (level - band_level)
    if reps == 1:
        return scale_a
    return jnp.repeat(scale_a, reps, axis=-1)
