"""GWT — Gradient Wavelet Transform optimizer (the paper's Algorithm 1).

Per eligible 2-D (or stacked ``(L, m, n)``) weight ``W`` with transform axis
width ``n`` divisible by ``2^l``::

    [A_t, D_t]  = G_t · H^l                      (multi-level DHT)
    M^R, V^R    = host-optimizer moments on A_t  (memory: shapes of A_t)
    Ã_t         = M^R / (√V^R + ε)
    D̃_k        = D_k · upsample(1/(√V^R+ε))     (scale consistency)
    G̃_t        = [Ã_t, D̃_t] · Hᵀ               (inverse DHT — full rank!)
    G̃_t        = NormGrowthLimiter(G̃_t)         (γ = 1.01)
    W_{t+1}     = W_t − η_t · α · G̃_t            (η_t: bias-corrected lr)

Ineligible leaves (embeddings, lm-head, norms, 1-D) run plain Adam at the
base lr — the paper's module-wise strategy.  ``level=0`` reduces exactly to
the host optimizer (tested).

The per-leaf routing is declared as rules over the shared bucketed engine
(``repro.optim.engine``): same-shaped eligible leaves are stacked into one
``(L, m, n)`` bucket and — on the fused path — go through
``kernels/gwt_adam/ops.fused_update`` in a **single** call per bucket.

``impl`` selects the kernel backend: ``'pallas'`` routes eligible-leaf
updates through the fused TPU kernel (`repro.kernels.gwt_adam`),
``'interpret'`` validates that lowering on CPU, ``'jnp'`` uses the pure
butterfly, and ``'auto'`` (default) resolves per platform via
``repro.compat`` — launchers pass ``MeshContext.kernel_impl`` explicitly.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import haar, limiter
from repro.optim import engine, hosts as hosts_lib
from repro.optim.base import Optimizer, default_eligible, flatten_with_paths
from repro.optim.schedules import Schedule, constant


class _Mode:
    PLAIN = "plain"       # host-ineligible: plain Adam on the full tensor
    LAST = "gwt_last"     # DHT along axis -1
    FIRST = "gwt_first"   # DHT along axis -2 (transposed)


def _leaf_mode(path: str, leaf, level: int,
               eligible: Callable[[str, jax.Array], bool]) -> str:
    block = 1 << level
    if level == 0 or not eligible(path, leaf):
        return _Mode.PLAIN
    if leaf.ndim >= 2 and leaf.shape[-1] % block == 0:
        return _Mode.LAST
    if leaf.ndim >= 2 and leaf.shape[-2] % block == 0:
        return _Mode.FIRST
    return _Mode.PLAIN


def gwt(lr: Schedule | float,
        level: int = 2,
        alpha: float = 0.25,
        host: str = "adam",
        host_kwargs: Optional[dict] = None,
        gamma: float = limiter.DEFAULT_GAMMA,
        use_limiter: bool = True,
        eligible: Callable[[str, jax.Array], bool] = None,
        weight_decay: float = 0.0,
        state_dtype=jnp.float32,
        wavelet: str = "haar",
        impl: str = "auto",
        fused_write: bool = True,
        bucketed: bool = True,
        state_shardings=None,
        state_codec="f32") -> Optimizer:
    """Build the GWT optimizer. ``host`` in {'adam','adam_mini','muon'};
    ``wavelet`` in {'haar' (paper), 'db2' (beyond-paper Daubechies-4)};
    ``state_shardings`` forwards per-bucket NamedSharding hints (from
    ``distributed.sharding.gwt_state_shardings(...)['buckets']``) to the
    engine so init/update keep optimizer state on the mesh layout.
    ``state_codec`` ('f32'|'int8') selects the moment substrate
    (``repro.optim.codec``): int8 composes multiplicatively with the
    wavelet subspace — host moments live on the ``A_l`` band AND are
    stored blocked-quantized.  On the fused kernel path the requantize
    epilogue runs inside the kernel (``ops.fused_update_q8``).
    ``fused_write=False`` keeps the DWT+Adam core kernel but stages the
    limiter/step/param-write outside it (the pre-megakernel dataflow,
    materializing g̃) — a benchmarking baseline, not a production knob."""
    from repro.optim import codec as codec_lib
    if wavelet not in ("haar", "db2"):
        raise ValueError(f"unknown wavelet {wavelet!r}")
    impl = compat.resolve_kernel_impl(impl)
    cdc = codec_lib.get_codec(state_codec)
    quant = not cdc.passthrough
    fwd = haar.haar_forward if wavelet == "haar" else haar.db2_forward
    inv = haar.haar_inverse if wavelet == "haar" else haar.db2_inverse
    if isinstance(lr, (int, float)):
        lr = constant(lr)
    host_kwargs = dict(host_kwargs or {})
    host_kwargs.setdefault("state_dtype", state_dtype)
    h = hosts_lib.make_host(host, **host_kwargs)
    # Ineligible leaves always run Adam (paper's module-wise strategy), even
    # for a MUON host (matches MUON-for-2D + Adam-for-rest practice).
    plain = hosts_lib.adam(state_dtype=state_dtype) if host == "muon" else h
    elig = eligible or default_eligible
    use_fused = impl != "jnp" and h.name == "adam" and wavelet == "haar"
    # the fused kernel takes the Adam coefficients explicitly — mirror the
    # host's (hosts.adam defaults), so host_kwargs overrides are honored on
    # every backend, not just the jnp core
    adam_kw = {k: host_kwargs.get(k, d)
               for k, d in (("b1", 0.9), ("b2", 0.999), ("eps", 1e-6))}

    def _gwt_core(g, hstate, step):
        a, details = fwd(g, level)
        precond_a, dscale, lr_mult, hstate = h.update(a, hstate, step)
        if dscale is None:
            tilde_d = list(details)
        else:
            tilde_d = [d * haar.detail_scale_upsample(dscale, level, level - i)
                       for i, d in enumerate(details)]
        g_tilde = inv(precond_a, tilde_d)
        return g_tilde, lr_mult, hstate

    def _apply(p, delta, lr_t, lr_mult, eff_alpha):
        step_size = (lr_t * lr_mult * eff_alpha).astype(jnp.float32)
        new_p = p.astype(jnp.float32) - step_size * delta.astype(jnp.float32)
        if weight_decay:
            new_p = new_p - lr_t * weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype)

    # -- plain rule: host optimizer on the full tensor ----------------------
    def plain_update(g, p, state, step, leaf_id):
        delta, _, lr_mult, hstate = plain.update(g, state["host"], step)
        return _apply(p, delta, lr(step), lr_mult, 1.0), {"host": hstate}

    plain_rule = engine.LeafRule(
        kind=_Mode.PLAIN, init=lambda p: {"host": plain.init(p)},
        update=plain_update, slots={"host": plain.slots})

    # -- GWT rules: DHT along axis -1 (LAST) or -2 (FIRST) ------------------
    def make_gwt_rule(mode: str) -> engine.LeafRule:
        swap = mode == _Mode.FIRST

        def init(p):
            g_shape = tuple(p.shape) if not swap \
                else tuple(p.shape[:-2]) + (p.shape[-1], p.shape[-2])
            a_shape = g_shape[:-1] + (g_shape[-1] >> level,)
            return {"host": h.init(jax.ShapeDtypeStruct(a_shape, state_dtype)),
                    "prev_norm": jnp.zeros((), jnp.float32)}

        def core(g, hstate, step):
            gt = jnp.swapaxes(g, -1, -2) if swap else g
            if use_fused:
                from repro.kernels.gwt_adam import ops as gwt_ops  # lazy
                g_tilde, lr_mult, hstate = gwt_ops.fused_update(
                    gt, hstate, step, level=level, impl=impl, **adam_kw)
            else:
                g_tilde, lr_mult, hstate = _gwt_core(gt, hstate, step)
            if swap:
                g_tilde = jnp.swapaxes(g_tilde, -1, -2)
            return g_tilde, lr_mult, hstate

        def update(g, p, state, step, leaf_id):
            g_tilde, lr_mult, hstate = core(g, state["host"], step)
            out = {"host": hstate, "prev_norm": state["prev_norm"]}
            if use_limiter:
                g_tilde, out["prev_norm"] = limiter.limit(
                    g_tilde, state["prev_norm"], gamma)
            return _apply(p, g_tilde, lr(step), lr_mult, alpha), out

        def vector_update(g_stk, p_stk, state, step, leaf_ids):
            # Fused-write megakernel: ONE launch for the whole (L, m, n)
            # bucket performs DWT→Adam→inverse→limit→param-write — the
            # limiter, bias-corrected step, and weight decay all run in
            # the kernel epilogue, so g̃ never round-trips HBM.
            from repro.kernels.gwt_adam import ops as gwt_ops  # lazy
            gt = jnp.swapaxes(g_stk, -1, -2) if swap else g_stk
            pt = jnp.swapaxes(p_stk, -1, -2) if swap else p_stk
            new_p, new_norm, hstate = gwt_ops.fused_write_update(
                gt, pt, state["host"], step, state["prev_norm"],
                lr_t=lr(step), alpha=alpha, weight_decay=weight_decay,
                gamma=gamma, use_limiter=use_limiter, level=level,
                impl=impl, **adam_kw)
            if swap:
                new_p = jnp.swapaxes(new_p, -1, -2)
            return new_p, {"host": hstate, "prev_norm": new_norm}

        def vector_update_q8(g_stk, p_stk, state, step, leaf_ids,
                             codec_key):
            # codec-native fused-write path: the kernel dequantizes the
            # blocked moments, updates, requantizes, AND applies
            # limit+step+write in one launch — decoded f32 moments and g̃
            # never round-trip HBM.  Slot salts (m=0, v=1) match
            # codec.map_slots' sorted-key order, so this path and the
            # generic scan wrap produce the same rounding bits.
            from repro.kernels.gwt_adam import ops as gwt_ops  # lazy
            gt = jnp.swapaxes(g_stk, -1, -2) if swap else g_stk
            pt = jnp.swapaxes(p_stk, -1, -2) if swap else p_stk
            new_p, new_norm, hstate = gwt_ops.fused_write_update_q8(
                gt, pt, state["host"], step, codec_key, leaf_ids,
                state["prev_norm"], lr_t=lr(step), alpha=alpha,
                weight_decay=weight_decay, gamma=gamma,
                use_limiter=use_limiter, level=level, block=cdc.block,
                impl=impl, **adam_kw)
            if swap:
                new_p = jnp.swapaxes(new_p, -1, -2)
            return new_p, {"host": hstate, "prev_norm": new_norm}

        def taps(g_stk, p_stk, new_p_stk, old_st, new_st, step):
            # Observability taps (DESIGN.md §12), traced only inside
            # tapped_update (the TrainLoop runs it once per chunk, on the
            # log_every boundary step).  Band energies come from the
            # approx averaging chain alone: the DHT is orthonormal, so
            # the detail energy is Parseval's remainder ssq(g) - ssq(A_l)
            # — no detail bands materialized.  Limiter taps piggyback on
            # the norm pass the update already ran — ``prev_norm`` IS the
            # fused kernel's norm output — so the post-limit update norm
            # and clip rate cost no new passes.
            gt = jnp.swapaxes(g_stk, -1, -2) if swap else g_stk
            gt32 = gt.astype(jnp.float32)
            a = haar.haar_approx(gt32, level) if wavelet == "haar" \
                else fwd(gt32, level)[0]
            band_a = jnp.sum(a * a)
            out = {"band_a_ssq": band_a,
                   "band_d_ssq": jnp.sum(gt32 * gt32) - band_a}
            if use_limiter:
                old_pn = old_st["prev_norm"]
                new_pn = new_st["prev_norm"]
                clipped = limiter.clip_flags(old_pn, new_pn, gamma)
                nleaves = g_stk.shape[0]
                out["gnorm_ssq"] = jnp.sum(new_pn * new_pn)
                out["clip_count"] = jnp.sum(clipped.astype(jnp.float32))
                out["clip_rate"] = out["clip_count"] / jnp.float32(nleaves)
            return out

        vu, native = None, False
        if use_fused and fused_write:
            vu, native = (vector_update_q8, True) if quant \
                else (vector_update, False)
        return engine.LeafRule(
            kind=mode, init=init, update=update, vector_update=vu,
            slots={"host": h.slots, "prev_norm": False},
            codec_native=native, taps=taps)

    gwt_last = make_gwt_rule(_Mode.LAST)
    gwt_first = make_gwt_rule(_Mode.FIRST)
    rules = {_Mode.PLAIN: plain_rule, _Mode.LAST: gwt_last,
             _Mode.FIRST: gwt_first}

    return engine.build(
        lambda path, leaf: rules[_leaf_mode(path, leaf, level, elig)],
        bucketed=bucketed, state_shardings=state_shardings,
        codec=cdc)


# ---------------------------------------------------------------------------
# Memory accounting (paper Table I / Table XI): optimizer-state bytes.
# ---------------------------------------------------------------------------

def _host_elements(shape, host: str) -> int:
    """State elements a host keeps for one tensor of ``shape``: Adam 2× (M+V),
    MUON 1× (momentum only), Adam-mini a full M plus one V per row."""
    size = 1
    for s in shape:
        size *= s
    if host == "muon":
        return size
    if host == "adam_mini":
        rows = size // shape[-1] if len(shape) >= 2 else 1
        return size + rows
    return 2 * size


def state_memory_bytes(params, level: int,
                       eligible: Callable[[str, jax.Array], bool] = None,
                       bytes_per_el: int = 2, host: str = "adam") -> Dict[str, int]:
    """Analytic optimizer-state memory: GWT leaves keep host states on the
    ``A_l`` band (``size/2^l`` elements), plain leaves host states on the
    full tensor.  Host multiplier: Adam 2× (M+V), MUON 1× (M only; plain
    leaves still run Adam), Adam-mini ``1× + 1/row`` (full M, per-row V).

    For *exact* per-optimizer accounting use
    ``repro.optim.engine.state_bytes(optimizer, params)``.
    """
    elig = eligible or default_eligible
    acc = {"gwt_bytes": 0, "plain_bytes": 0, "gwt_params": 0, "plain_params": 0}
    plain_host = "adam" if host == "muon" else host
    paths, leaves, _ = flatten_with_paths(params)
    for path, p in zip(paths, leaves):
        mode = _leaf_mode(path, p, level, elig)
        if mode == _Mode.PLAIN:
            acc["plain_bytes"] += _host_elements(tuple(p.shape),
                                                 plain_host) * bytes_per_el
            acc["plain_params"] += p.size
        else:
            width = (p.shape[-1] if mode == _Mode.LAST
                     else p.shape[-2]) >> level
            a_shape = (p.size // (width << level), width)
            acc["gwt_bytes"] += _host_elements(a_shape, host) * bytes_per_el
            acc["gwt_params"] += p.size
    acc["total_bytes"] = acc["gwt_bytes"] + acc["plain_bytes"]
    return acc
