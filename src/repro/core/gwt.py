"""GWT — Gradient Wavelet Transform optimizer (the paper's Algorithm 1).

Per eligible 2-D (or stacked ``(L, m, n)``) weight ``W`` with transform axis
width ``n`` divisible by ``2^l``::

    [A_t, D_t]  = G_t · H^l                      (multi-level DHT)
    M^R, V^R    = host-optimizer moments on A_t  (memory: shapes of A_t)
    Ã_t         = M^R / (√V^R + ε)
    D̃_k        = D_k · upsample(1/(√V^R+ε))     (scale consistency)
    G̃_t        = [Ã_t, D̃_t] · Hᵀ               (inverse DHT — full rank!)
    G̃_t        = NormGrowthLimiter(G̃_t)         (γ = 1.01)
    W_{t+1}     = W_t − η_t · α · G̃_t            (η_t: bias-corrected lr)

Ineligible leaves (embeddings, lm-head, norms, 1-D) run plain Adam at the
base lr — the paper's module-wise strategy.  ``level=0`` reduces exactly to
the host optimizer (tested).

``impl`` selects the kernel backend: ``'pallas'`` routes eligible-leaf
updates through the fused TPU kernel (`repro.kernels.gwt_adam`),
``'interpret'`` validates that lowering on CPU, ``'jnp'`` uses the pure
butterfly, and ``'auto'`` (default) resolves per platform via
``repro.compat`` — launchers pass ``MeshContext.kernel_impl`` explicitly.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import haar, limiter
from repro.optim import hosts as hosts_lib
from repro.optim.base import Optimizer, default_eligible, flatten_with_paths
from repro.optim.schedules import Schedule, constant


class _Mode:
    PLAIN = "plain"       # host-ineligible: plain Adam on the full tensor
    LAST = "gwt_last"     # DHT along axis -1
    FIRST = "gwt_first"   # DHT along axis -2 (transposed)


def _leaf_mode(path: str, leaf, level: int,
               eligible: Callable[[str, jax.Array], bool]) -> str:
    block = 1 << level
    if level == 0 or not eligible(path, leaf):
        return _Mode.PLAIN
    if leaf.ndim >= 2 and leaf.shape[-1] % block == 0:
        return _Mode.LAST
    if leaf.ndim >= 2 and leaf.shape[-2] % block == 0:
        return _Mode.FIRST
    return _Mode.PLAIN


def gwt(lr: Schedule | float,
        level: int = 2,
        alpha: float = 0.25,
        host: str = "adam",
        host_kwargs: Optional[dict] = None,
        gamma: float = limiter.DEFAULT_GAMMA,
        use_limiter: bool = True,
        eligible: Callable[[str, jax.Array], bool] = None,
        weight_decay: float = 0.0,
        state_dtype=jnp.float32,
        wavelet: str = "haar",
        impl: str = "auto") -> Optimizer:
    """Build the GWT optimizer. ``host`` in {'adam','adam_mini','muon'};
    ``wavelet`` in {'haar' (paper), 'db2' (beyond-paper Daubechies-4)}."""
    if wavelet not in ("haar", "db2"):
        raise ValueError(f"unknown wavelet {wavelet!r}")
    impl = compat.resolve_kernel_impl(impl)
    fwd = haar.haar_forward if wavelet == "haar" else haar.db2_forward
    inv = haar.haar_inverse if wavelet == "haar" else haar.db2_inverse
    if isinstance(lr, (int, float)):
        lr = constant(lr)
    host_kwargs = dict(host_kwargs or {})
    host_kwargs.setdefault("state_dtype", state_dtype)
    h = hosts_lib.make_host(host, **host_kwargs)
    # Ineligible leaves always run Adam (paper's module-wise strategy), even
    # for a MUON host (matches MUON-for-2D + Adam-for-rest practice).
    plain = hosts_lib.adam(state_dtype=state_dtype) if host == "muon" else h
    elig = eligible or default_eligible

    def init(params):
        paths, leaves, _ = flatten_with_paths(params)
        leaf_states = []
        for path, p in zip(paths, leaves):
            mode = _leaf_mode(path, p, level, elig)
            if mode == _Mode.PLAIN:
                leaf_states.append({"host": plain.init(p)})
            else:
                g_shape = p.shape if mode == _Mode.LAST \
                    else p.shape[:-2] + (p.shape[-1], p.shape[-2])
                a_shape = g_shape[:-1] + (g_shape[-1] >> level,)
                leaf_states.append({
                    "host": h.init(jax.ShapeDtypeStruct(a_shape, state_dtype)),
                    "prev_norm": jnp.zeros((), jnp.float32),
                })
        return {"step": jnp.zeros((), jnp.int32), "leaves": tuple(leaf_states)}

    def _gwt_core(g, hstate, step):
        a, details = fwd(g, level)
        precond_a, dscale, lr_mult, hstate = h.update(a, hstate, step)
        if dscale is None:
            tilde_d = list(details)
        else:
            tilde_d = [d * haar.detail_scale_upsample(dscale, level, level - i)
                       for i, d in enumerate(details)]
        g_tilde = inv(precond_a, tilde_d)
        return g_tilde, lr_mult, hstate

    def update(grads, state, params):
        step = state["step"]
        lr_t = lr(step)
        paths, gleaves, treedef = flatten_with_paths(grads)
        pleaves = jax.tree_util.tree_leaves(params)
        new_params, new_states = [], []
        for path, g, lstate, p in zip(paths, gleaves, state["leaves"], pleaves):
            mode = _leaf_mode(path, p, level, elig)
            out = dict(lstate)
            if mode == _Mode.PLAIN:
                delta, _, lr_mult, out["host"] = plain.update(g, lstate["host"], step)
                eff_alpha = 1.0
            else:
                gt = g if mode == _Mode.LAST else jnp.swapaxes(g, -1, -2)
                if impl != "jnp" and h.name == "adam" and wavelet == "haar":
                    from repro.kernels.gwt_adam import ops as gwt_ops  # lazy
                    g_tilde, lr_mult, out["host"] = gwt_ops.fused_update(
                        gt, lstate["host"], step, level=level, impl=impl)
                else:
                    g_tilde, lr_mult, out["host"] = _gwt_core(gt, lstate["host"], step)
                if mode == _Mode.FIRST:
                    g_tilde = jnp.swapaxes(g_tilde, -1, -2)
                if use_limiter:
                    g_tilde, out["prev_norm"] = limiter.limit(
                        g_tilde, lstate["prev_norm"], gamma)
                delta = g_tilde
                eff_alpha = alpha
            step_size = (lr_t * lr_mult * eff_alpha).astype(jnp.float32)
            new_p = p.astype(jnp.float32) - step_size * delta.astype(jnp.float32)
            if weight_decay:
                new_p = new_p - lr_t * weight_decay * p.astype(jnp.float32)
            new_params.append(new_p.astype(p.dtype))
            new_states.append(out)
        return (jax.tree_util.tree_unflatten(treedef, new_params),
                {"step": step + 1, "leaves": tuple(new_states)})

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Memory accounting (paper Table I / Table XI): optimizer-state bytes.
# ---------------------------------------------------------------------------

def state_memory_bytes(params, level: int,
                       eligible: Callable[[str, jax.Array], bool] = None,
                       bytes_per_el: int = 2, host: str = "adam") -> Dict[str, int]:
    """Optimizer-state memory: GWT leaves keep ``2·size/2^l`` elements
    (M^R+V^R), plain leaves ``2·size`` (Adam M+V); MUON host keeps 1× not 2×.
    """
    elig = eligible or default_eligible
    per_state = 1 if host == "muon" else 2
    acc = {"gwt_bytes": 0, "plain_bytes": 0, "gwt_params": 0, "plain_params": 0}
    paths, leaves, _ = flatten_with_paths(params)
    for path, p in zip(paths, leaves):
        mode = _leaf_mode(path, p, level, elig)
        if mode == _Mode.PLAIN:
            acc["plain_bytes"] += 2 * p.size * bytes_per_el
            acc["plain_params"] += p.size
        else:
            acc["gwt_bytes"] += per_state * (p.size >> level) * bytes_per_el
            acc["gwt_params"] += p.size
    acc["total_bytes"] = acc["gwt_bytes"] + acc["plain_bytes"]
    return acc
