"""Wavelet-domain gradient compression for data-parallel reduction
(beyond-paper extension; DESIGN.md §3 — wired into the executed train
path by ``models/lm.py:make_train_step(dp_reduce=...)``).

The paper compresses *optimizer states* in the Haar domain.  The same
frequency split compresses *DP gradient traffic*: all-reduce the
approximation band ``A_l`` at full precision and the detail bands ``D_k``
at reduced precision (bf16 / f8).  Because the DHT is linear and
orthonormal, ``mean(G_i) = IDWT(mean(DWT(G_i)))`` exactly; the only error
is detail-band quantization — which the paper's own analysis (Theorem 1:
detail bands carry the part a low-rank/low-pass approximation would drop)
argues is the tolerant part of the spectrum.

Wire bytes per element at level l vs the 4B f32 all-reduce:
``(1/2^l)·4B + (1 − 1/2^l)·detail_bytes`` — 1.6× less at l=2 with bf16
details (→2× as l grows), 2.29× at l=2 / 3.37× at l=4 with f8 details.
The ``psum`` runs directly on the wire-dtype arrays, so these ratios
describe the payload the reduction actually ships; a production f8
deployment would add per-block scale factors to recover the narrow
e4m3 exponent range (see ROADMAP).

Structure: the wavelet split / quantize (:func:`reduce_terms`) and the
reconstruction (:func:`reconstruct`) are *pure per-shard math* — property
tests drive them against an emulated sequential reduction without any
mesh — while :func:`compressed_psum_mean` is that math wrapped around
``lax.psum`` inside a ``shard_map``/``pmap`` axis context.
``detail_dtype=None`` (or ``level == 0``) short-circuits to the exact
``psum`` mean — the lossless mode of the sharded train path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import haar


@dataclasses.dataclass(frozen=True)
class DPReduceSpec:
    """How the sharded train step reduces gradients over the DP axis.

    * ``level`` — wavelet levels for the split (compression grows with it).
    * ``detail_dtype`` — dtype the detail bands travel in; ``None`` means
      no compression: the reduction is one exact f32 ``psum``.
    * ``error_feedback`` — accumulate each worker's local quantization
      residue and add it back before the next compressed reduction
      (``--dp-error-feedback``): the bias of the compressed mean stops
      persisting step-over-step and averages out instead (see
      :func:`compressed_psum_mean_ef`).  No effect on the exact path.
    """

    level: int = 2
    detail_dtype: Any = jnp.bfloat16
    error_feedback: bool = False

    @property
    def exact(self) -> bool:
        return self.detail_dtype is None or self.level == 0

    @classmethod
    def parse(cls, mode: str, level: int = 2,
              detail_dtype: str = "bfloat16",
              error_feedback: bool = False) -> Optional["DPReduceSpec"]:
        """Launcher-flag constructor: ``none`` | ``exact`` | ``compressed``."""
        if mode in ("", "none"):
            if error_feedback:
                raise ValueError("--dp-error-feedback needs --dp-reduce "
                                 "compressed")
            return None
        if mode == "exact":
            if error_feedback:
                raise ValueError("--dp-error-feedback is meaningless for "
                                 "the exact (lossless) reduction — use "
                                 "--dp-reduce compressed")
            return cls(level=level, detail_dtype=None)
        if mode == "compressed":
            return cls(level=level, detail_dtype=jnp.dtype(detail_dtype),
                       error_feedback=error_feedback)
        raise ValueError(f"unknown dp-reduce mode {mode!r}; "
                         "choices: none|exact|compressed")


def compressible(shape: Sequence[int], level: int) -> bool:
    """Leaves the wavelet split applies to; the rest take the exact-psum
    fallback (1-D tensors, widths not divisible by the transform block)."""
    return len(shape) >= 2 and level > 0 and shape[-1] % (1 << level) == 0


def reduce_terms(g: jax.Array, level: int, detail_dtype, impl: str = "jnp"
                 ) -> Tuple[jax.Array, List[jax.Array]]:
    """Per-shard wire terms: f32 approximation band + quantized details.

    This is exactly what each worker contributes to the all-reduce — the
    detail arrays are *in* the wire dtype, and the ``psum`` runs on them
    as-is, so :func:`tree_wire_bytes` describes the payload the reduction
    actually moves (XLA's all-reduce may still accumulate wider
    internally and round once; see ``_psum_like_sum``).  The error of the
    whole scheme is the quantization applied HERE plus that single
    accumulation rounding.

    ``impl`` pallas/interpret routes the split through the fused
    quantize+pack Pallas kernel (``haar_dwt.ops.dwt_wire``): the detail
    cast happens at the tile write, so the f32 detail intermediates never
    materialize in HBM.  ``auto``/``None`` resolve per platform via
    ``compat.resolve_kernel_impl`` (pallas on TPU), matching every other
    kernel entry point.  The butterfly is elementwise — no reductions —
    so the kernel's terms are bitwise the jnp ones regardless of tiling
    (pinned by tests/test_kernels.py)."""
    impl = compat.resolve_kernel_impl(impl)
    if impl != "jnp":
        from repro.kernels.haar_dwt import ops as dwt_ops
        lead = g.shape[:-1]
        flat = g.astype(jnp.float32).reshape(-1, g.shape[-1])
        bands = dwt_ops.dwt_wire(flat, level, detail_dtype, impl=impl)
        return (bands[0].reshape(*lead, -1),
                [d.reshape(*lead, -1) for d in bands[1:]])
    a, ds = haar.haar_forward(g.astype(jnp.float32), level)
    return a, [d.astype(detail_dtype) for d in ds]


def reconstruct(a: jax.Array, ds: Sequence[jax.Array], n) -> jax.Array:
    """Inverse of :func:`reduce_terms` after the cross-worker sum:
    details widen back to f32 and everything divides by the worker count
    ``n`` (the summed terms are means after this)."""
    a = a / n
    ds = [d.astype(jnp.float32) / n for d in ds]
    return haar.haar_inverse(a, ds)


def compressed_psum_mean(g: jax.Array, axis_name, level: int = 2,
                         detail_dtype=jnp.bfloat16,
                         impl: str = "jnp") -> jax.Array:
    """Mean-reduce ``g`` over ``axis_name`` inside shard_map/pmap context,
    wavelet-split: A_l in f32, D_k in ``detail_dtype``.

    ``detail_dtype=None`` (or ``level == 0``) is the EXACT mode: a single
    f32 ``psum`` — the sharded train path's lossless reduction, bitwise
    equal to a sequential device-order sum (tests/test_sharded_train.py).
    Non-compressible leaves always take that exact path.  ``impl`` routes
    the wavelet split through the fused Pallas quantize+pack kernel (see
    :func:`reduce_terms`)."""
    n = jax.lax.psum(1, axis_name)
    if detail_dtype is None or level == 0 or not compressible(g.shape, level):
        return jax.lax.psum(g.astype(jnp.float32), axis_name) / n
    a, ds = reduce_terms(g, level, detail_dtype, impl)
    a = jax.lax.psum(a, axis_name)
    ds = [jax.lax.psum(d, axis_name) for d in ds]
    return reconstruct(a, ds, n)


# ---------------------------------------------------------------------------
# Error feedback (the ROADMAP designed-but-unbuilt hook, now built):
# each worker keeps the residue its own quantization discarded and adds it
# back to the next local gradient before the next compressed reduction.
# The compensated per-round means then satisfy  sum_t r_t ≈ sum_t mean(g_t)
# (the residue telescopes), so the time-averaged bias of the compressed
# reduction shrinks ~1/T instead of persisting (tested in
# tests/test_data_subsystem.py).  The residue is PURELY LOCAL state — it
# never travels on the wire, and the exact / non-compressible paths keep
# it at zero.
# ---------------------------------------------------------------------------

def local_residual(gc: jax.Array, a: jax.Array, ds) -> jax.Array:
    """What this worker's quantization discarded: the compensated local
    gradient minus what the wire terms reconstruct to (``n=1``: no
    cross-worker divide)."""
    return gc - reconstruct(a, ds, 1)


def compressed_psum_mean_ef(g: jax.Array, err: jax.Array, axis_name,
                            level: int = 2, detail_dtype=jnp.bfloat16,
                            impl: str = "jnp"
                            ) -> Tuple[jax.Array, jax.Array]:
    """:func:`compressed_psum_mean` with error feedback: returns
    ``(mean, new_err)``.  Non-compressible/exact leaves take the exact
    psum and keep a zero residue."""
    n = jax.lax.psum(1, axis_name)
    if detail_dtype is None or level == 0 or not compressible(g.shape, level):
        return jax.lax.psum(g.astype(jnp.float32), axis_name) / n, \
            jnp.zeros_like(err)
    gc = g.astype(jnp.float32) + err
    a, ds = reduce_terms(gc, level, detail_dtype, impl)
    new_err = local_residual(gc, a, ds)
    a = jax.lax.psum(a, axis_name)
    ds = [jax.lax.psum(d, axis_name) for d in ds]
    return reconstruct(a, ds, n), new_err


def ef_init(tree, dp_size: int = 1):
    """Zero residue state for a gradient tree: one f32 leaf per gradient
    leaf with a leading per-worker axis (shard it over the DP axis — each
    device owns exactly its own residue).  Leaves that ride the exact
    psum simply stay zero."""
    return jax.tree.map(
        lambda p: jnp.zeros((dp_size,) + tuple(p.shape), jnp.float32), tree)


def ef_state_shardings(ef_tree, mesh, dp_axis_names: Sequence[str]):
    """NamedShardings pinning each residue leaf's leading per-worker axis
    to the DP mesh axes (each device holds exactly its own residue)."""
    from jax.sharding import NamedSharding
    mesh = compat.unwrap_mesh(mesh)
    axis = tuple(dp_axis_names) if len(dp_axis_names) > 1 \
        else dp_axis_names[0]
    return jax.tree.map(
        lambda e: NamedSharding(mesh, P(axis, *([None] * (e.ndim - 1)))),
        ef_tree)


@functools.partial(jax.jit, static_argnums=(2, 3))
def emulated_mean_ef(g_stack: jax.Array, err_stack: jax.Array, level: int,
                     detail_dtype) -> Tuple[jax.Array, jax.Array]:
    """Reference semantics of :func:`compressed_psum_mean_ef` on stacked
    ``(n_workers, ...)`` arrays, no mesh required (same worker-order
    sequential sum as :func:`emulated_mean`).  Returns
    ``(mean, new_err_stack)`` — drives the bias-shrink property test."""
    n = g_stack.shape[0]
    local_shape = (1,) + tuple(g_stack.shape[1:])
    if detail_dtype is None or level == 0 \
            or not compressible(local_shape, level):
        return _psum_like_sum(g_stack.astype(jnp.float32)) / n, \
            jnp.zeros_like(err_stack)
    terms, errs = [], []
    for i in range(n):
        gc = g_stack[i:i + 1].astype(jnp.float32) + err_stack[i:i + 1]
        a, ds = haar.haar_forward(gc, level)
        ds = [d.astype(detail_dtype) for d in ds]
        errs.append(local_residual(gc, a, ds))
        terms.append((a, ds))
    a = _psum_like_sum(jnp.stack([t[0] for t in terms]))
    ds = [_psum_like_sum(jnp.stack([t[1][k] for t in terms]))
          for k in range(len(terms[0][1]))]
    return reconstruct(a, ds, n)[0], jnp.concatenate(errs, axis=0)


@functools.partial(jax.jit, static_argnums=(1, 2))
def emulated_mean(g_stack: jax.Array, level: int, detail_dtype) -> jax.Array:
    """Reference semantics of :func:`compressed_psum_mean` on a stacked
    ``(n_workers, ...)`` array, no mesh required: per-worker terms summed
    sequentially in worker order — the same order the CPU backend's
    ``psum`` uses (asserted bitwise in tests/test_sharded_train.py).

    Each worker's payload keeps its leading length-1 axis, exactly what a
    ``shard_map`` over the stacked dim hands ``compressed_psum_mean`` —
    so the compressibility decision matches the real path's local view
    (a ``(D, n)`` stack of 1-D payloads with ``n`` divisible compresses
    in BOTH, as ``(1, n)`` blocks).  Jitted (static ``level``/
    ``detail_dtype``): the bitwise contract holds for the compiled
    pipeline; eagerly dispatched ops fuse differently and drift an f32
    ulp.

    Bitwise for the exact and bf16 modes; for f8 payloads the backend's
    all-reduce accumulation strategy is buffer-size-dependent, so the
    match is within one f8 detail ulp instead (the train path's bitwise
    guarantees only ever ride the EXACT mode — compressed modes carry
    error bounds, not bit contracts)."""
    n = g_stack.shape[0]
    local_shape = (1,) + tuple(g_stack.shape[1:])
    if detail_dtype is None or level == 0 \
            or not compressible(local_shape, level):
        return _psum_like_sum(g_stack.astype(jnp.float32)) / n
    terms = [reduce_terms(g_stack[i:i + 1], level, detail_dtype)
             for i in range(n)]
    a = _psum_like_sum(jnp.stack([t[0] for t in terms]))
    ds = [_psum_like_sum(jnp.stack([t[1][k] for t in terms]))
          for k in range(len(terms[0][1]))]
    return reconstruct(a, ds, n)[0]


def _psum_like_sum(stack: jax.Array) -> jax.Array:
    """``psum`` semantics on the CPU backend, observed and pinned by
    tests/test_sharded_train.py: accumulate in f32 in worker order
    (sequential, not ``jnp.sum``'s tree), round ONCE to the input dtype —
    sub-f32 payloads are NOT re-rounded per partial sum."""
    def body(acc, x):
        return acc + x, None
    acc, _ = jax.lax.scan(body, jnp.zeros(stack.shape[1:], jnp.float32),
                          stack.astype(jnp.float32))
    return acc.astype(stack.dtype)


def make_compressed_grad_reducer(mesh, axis: str = "data", level: int = 2,
                                 detail_dtype=jnp.bfloat16,
                                 impl: str = "jnp"):
    """Tree-wise reducer: local per-shard grads -> mean over the DP axis.

    ``mesh`` may be a concrete Mesh or a MeshContext.  Expects grad leaves
    replicated over every mesh axis except ``axis`` (pure-DP layout).
    Returns a jit-compatible callable.
    """
    mesh = compat.unwrap_mesh(mesh)

    def reduce_tree(grads):
        def one(g):
            fn = compat.shard_map(
                functools.partial(compressed_psum_mean, axis_name=axis,
                                  level=level, detail_dtype=detail_dtype,
                                  impl=impl),
                mesh,
                in_specs=P(axis, *([None] * (g.ndim - 1))),
                out_specs=P(axis, *([None] * (g.ndim - 1))),
            )
            return fn(g)
        return jax.tree.map(one, grads)

    return reduce_tree


def wire_bytes(num_elements: int, level: int, detail_bytes: int = 2,
               approx_bytes: int = 4) -> int:
    """Bytes on the wire per worker per reduction (ring, ≈2× payload)."""
    approx = num_elements >> level
    detail = num_elements - approx
    return 2 * (approx * approx_bytes + detail * detail_bytes)


def tree_wire_bytes(grads_abstract, dp: Optional[DPReduceSpec]) -> int:
    """Per-worker DP all-reduce wire bytes for a whole gradient tree under
    ``dp`` (``None`` or exact → full-f32 accounting).  Non-compressible
    leaves ride the exact psum and are charged at full f32 either way."""
    total = 0
    for leaf in jax.tree.leaves(grads_abstract):
        if dp is None or dp.exact or not compressible(leaf.shape, dp.level):
            total += wire_bytes(leaf.size, 0)
        else:
            total += wire_bytes(leaf.size, dp.level,
                                detail_bytes=jnp.dtype(dp.detail_dtype).itemsize)
    return total
