"""Wavelet-domain gradient compression for data-parallel reduction
(beyond-paper extension; DESIGN.md §3).

The paper compresses *optimizer states* in the Haar domain.  The same
frequency split compresses *DP gradient traffic*: all-reduce the
approximation band ``A_l`` at full precision and the detail bands ``D_k``
at reduced precision (bf16 / f8).  Because the DHT is linear and
orthonormal, ``mean(G_i) = IDWT(mean(DWT(G_i)))`` exactly; the only error
is detail-band quantization — which the paper's own analysis (Theorem 1:
detail bands carry the part a low-rank/low-pass approximation would drop)
argues is the tolerant part of the spectrum.

Wire savings at level l with bf16 details and f32 approximation vs f32
all-reduce: ``(1/2^l) · 4B + (1 − 1/2^l) · 2B`` vs ``4B`` → 2× at l≥2
(and ~3.7× with f8 details).

Implemented with ``shard_map`` + ``lax.psum`` over the DP axis so it
composes under jit with the rest of the (auto-sharded) step.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import haar


def compressed_psum_mean(g: jax.Array, axis_name: str, level: int = 2,
                         detail_dtype=jnp.bfloat16) -> jax.Array:
    """Mean-reduce ``g`` over ``axis_name`` inside shard_map/pmap context,
    wavelet-split: A_l in f32, D_k in ``detail_dtype``."""
    n = jax.lax.psum(1, axis_name)
    if g.ndim < 2 or g.shape[-1] % (1 << level):
        return jax.lax.psum(g.astype(jnp.float32), axis_name) / n
    a, ds = haar.haar_forward(g.astype(jnp.float32), level)
    a = jax.lax.psum(a, axis_name) / n
    ds = [jax.lax.psum(d.astype(detail_dtype), axis_name).astype(jnp.float32) / n
          for d in ds]
    return haar.haar_inverse(a, ds)


def make_compressed_grad_reducer(mesh, axis: str = "data", level: int = 2,
                                 detail_dtype=jnp.bfloat16):
    """Tree-wise reducer: local per-shard grads -> mean over the DP axis.

    ``mesh`` may be a concrete Mesh or a MeshContext.  Expects grad leaves
    replicated over every mesh axis except ``axis`` (pure-DP layout).
    Returns a jit-compatible callable.
    """
    from jax.experimental.shard_map import shard_map
    from repro import compat
    mesh = compat.unwrap_mesh(mesh)

    def reduce_tree(grads):
        def one(g):
            fn = shard_map(
                functools.partial(compressed_psum_mean, axis_name=axis,
                                  level=level, detail_dtype=detail_dtype),
                mesh=mesh,
                in_specs=P(axis, *([None] * (g.ndim - 1))),
                out_specs=P(axis, *([None] * (g.ndim - 1))),
            )
            return fn(g)
        return jax.tree.map(one, grads)

    return reduce_tree


def wire_bytes(num_elements: int, level: int, detail_bytes: int = 2,
               approx_bytes: int = 4) -> int:
    """Bytes on the wire per worker per reduction (ring, ≈2× payload)."""
    approx = num_elements >> level
    detail = num_elements - approx
    return 2 * (approx * approx_bytes + detail * detail_bytes)
