"""Logical-axis → mesh-axis sharding rules (DP/FSDP/TP/EP/SP).

One rule table covers all 10 architectures because parameters carry logical
axis names (repro.models.layers.Builder).  Rule values are *preference
lists*: the first candidate whose mesh axes are (a) not yet used by another
dim of the same tensor and (b) divide the dim size is taken; otherwise the
dim is replicated.  This resolves, automatically:

* GQA kv_heads (8) on a 16-way model axis  → replicated KV, sharded Q;
* qwen2-moe's 60 experts on 16-way model   → EP falls back to TP-in-expert
  (``expert_mlp`` takes the model axis instead);
* seamless' 256206 vocab (∤16)             → replicated vocab dim;
* long_500k's batch=1                      → batch replicated, cache
  sequence sharded over model×data (SP decode).
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.models.layers import Axes

Candidate = Union[str, Tuple[str, ...]]
Rules = Dict[str, Tuple[Candidate, ...]]


def _dp_axes(mesh) -> Tuple[str, ...]:
    mesh = compat.unwrap_mesh(mesh)
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def train_rules(mesh) -> Rules:
    """FSDP(data) × TP/EP(model); DP batch over (pod×)data.  Parameters are
    *not* sharded over the pod axis (cross-DCI all-gathers per layer would
    dominate) — the pod axis carries pure DP + gradient reduction."""
    dp = _dp_axes(mesh)
    return {
        "vocab": ("model",),
        "embed": ("data",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "expert": ("model",),       # EP when E % 16 == 0, else falls through
        "expert_mlp": ("model",),   # ...to TP inside the expert
        "inner": ("model",),
        "layers": (),
        "batch": (dp,),
        "seq": (),
    }


def decode_rules(mesh) -> Rules:
    """Decode: cache sequence axis gets the model axis (SP); for batch=1
    cells the sequence takes model×data."""
    dp = _dp_axes(mesh)
    return {
        "vocab": ("model",),
        "embed": ("data",),
        "heads": ("model",),
        "kv_heads": (),             # cache seq owns the model axis
        "mlp": ("model",),
        "expert": ("model",),
        "expert_mlp": ("model",),
        "inner": ("model",),
        "layers": (),
        "batch": (dp,),
        "seq": (("model",) + dp, ("model",) + dp[:1], "model"),
    }


def _axis_size(mesh, cand: Candidate) -> int:
    names = (cand,) if isinstance(cand, str) else cand
    return math.prod(mesh.shape[a] for a in names)


def spec_for(shape: Sequence[int], axes: Axes, mesh, rules: Rules) -> P:
    mesh = compat.unwrap_mesh(mesh)
    used = set()
    entries = []
    for size, name in zip(shape, axes.names):
        picked = None
        if name is not None:
            for cand in rules.get(name, ()):
                cand_names = (cand,) if isinstance(cand, str) else tuple(cand)
                if not cand_names:
                    continue
                # a rule may name an axis the mesh doesn't have (e.g. the
                # 'model' candidates on a pure-DP '--mesh 8' launch): fall
                # through to the next candidate / replication
                if any(a not in mesh.shape for a in cand_names):
                    continue
                if any(a in used for a in cand_names):
                    continue
                if size % _axis_size(mesh, cand) != 0:
                    continue
                picked = cand_names if len(cand_names) > 1 else cand_names[0]
                used.update(cand_names)
                break
        entries.append(picked)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(abstract: Any, axes_tree: Any, mesh, rules: Rules):
    """Map (ShapeDtypeStruct tree, Axes tree) -> NamedSharding tree."""
    mesh = compat.unwrap_mesh(mesh)

    def one(sds, ax):
        if ax is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, spec_for(sds.shape, ax, mesh, rules))
    return jax.tree.map(one, abstract, axes_tree,
                        is_leaf=lambda x: x is None or isinstance(x, Axes))


# ---------------------------------------------------------------------------
# Optimizer-state shardings (mirrors the engine's bucketed leaf plan)
# ---------------------------------------------------------------------------

def _stacked(mesh, spec: P) -> NamedSharding:
    """Per-leaf spec -> spec for the (L, ...) bucket stack: leading axis
    (the stacked same-shape leaves) is replicated, like the 'layers' dim."""
    return NamedSharding(mesh, P(*((None,) + tuple(spec))))


def gwt_state_shardings(params_abstract, params_axes, mesh, rules: Rules,
                        level: int, eligible=None, host: str = "adam",
                        state_codec: str = "f32"):
    """NamedSharding tree for the GWT optimizer's bucketed state layout
    ``{"step", ["codec_key",] "buckets": {name: {"host": ..., "prev_norm"?}}}``.

    Each bucket stacks same-shape leaves.  The host moments get the spec
    shared by *all* members' logical axes; when same-shape members resolve
    to different specs (e.g. ``attn/wq`` ('embed','heads') vs ``attn/wo``
    ('heads','embed') when ``H·hd == d`` — the engine buckets by shape
    only), the bucket's state is replicated rather than mis-sharding half
    the stack with a transposed partitioning.

    Under a quantizing ``state_codec`` each moment leaf becomes an encoded
    slot ``{"q": int8, "scale": f32}``: ``q`` keeps the moment's spec (same
    shape, just narrower dtype); the per-block ``scale`` vector is tiny
    (size/64 f32) and blocks don't align with any logical axis, so it is
    replicated."""
    from repro.core.gwt import _Mode, gwt as gwt_optimizer
    from repro.optim.base import flatten_with_paths
    from repro.optim import codec as codec_lib
    mesh = compat.unwrap_mesh(mesh)
    quant = not codec_lib.get_codec(state_codec).passthrough

    opt = gwt_optimizer(lr=0.0, level=level, host=host, eligible=eligible,
                        impl="jnp")
    plan = opt.engine.plan(params_abstract)
    _, pleaves, _ = flatten_with_paths(params_abstract)
    aleaves = jax.tree.leaves(params_axes,
                              is_leaf=lambda x: isinstance(x, Axes))
    rep = NamedSharding(mesh, P())

    def member_spec(kind: str, i: int) -> P:
        sds, ax = pleaves[i], aleaves[i]
        if kind == _Mode.PLAIN:
            return spec_for(sds.shape, ax, mesh, rules)
        if kind == _Mode.FIRST:
            names = ax.names[:-2] + (ax.names[-1], ax.names[-2])
            shape = sds.shape[:-2] + (sds.shape[-1], sds.shape[-2])
        else:
            names, shape = ax.names, sds.shape
        a_shape = shape[:-1] + (shape[-1] >> level,)
        return spec_for(a_shape, Axes(names), mesh, rules)

    def slot(sh):
        return {"q": sh, "scale": rep} if quant else sh

    bucket_shardings = {}
    for b in plan.buckets:
        specs = {member_spec(b.rule.kind, i) for i in b.indices}
        sh = _stacked(mesh, specs.pop()) if len(specs) == 1 else rep
        host_sh = {"m": slot(sh), "v": slot(sh)}
        if host == "adam_mini":
            host_sh["v"] = slot(rep)
        if b.rule.kind == _Mode.PLAIN:
            # plain leaves run Adam under a MUON host (module-wise policy)
            bucket_shardings[b.name] = {"host": host_sh}
        else:
            if host == "muon":
                host_sh = {"m": slot(sh)}
            bucket_shardings[b.name] = {"host": host_sh, "prev_norm": rep}
    out = {"step": rep, "buckets": bucket_shardings}
    if quant:
        out["codec_key"] = rep
    return out


class StepShardings(NamedTuple):
    """The three sharding trees the mesh-aware train step pins: params,
    optimizer state, and input batch (NamedSharding leaves; ``opt`` may be
    ``None`` when no per-bucket layout is known for the optimizer)."""

    params: Any
    opt: Any
    batch: Dict[str, Any]


def replicated_like(tree, mesh):
    """A fully-replicated NamedSharding tree shaped like ``tree`` — the
    classic-DP layout (``--shard-params none``)."""
    mesh = compat.unwrap_mesh(mesh)
    rep = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: rep, tree)


def train_step_shardings(cfg, mod, batch_abstract, mesh, *,
                         optimizer_name: str = "gwt", level: int = 2,
                         host: str = "adam", eligible=None,
                         shard_params: bool = True,
                         state_codec: str = "f32") -> StepShardings:
    """One-stop sharding-tree builder for the sharded train path
    (launch/train.py, benchmarks, tests).

    ``shard_params=True`` applies :func:`train_rules` (FSDP over 'data',
    TP over 'model' where present) to params and — for the GWT optimizer —
    the mirrored per-bucket layout to optimizer state.  ``False`` pins
    everything replicated (pure DP; the numerics-preserving layout the
    bitwise topology-equivalence tier runs under).  Batch inputs always
    shard over the DP axes."""
    mesh = compat.unwrap_mesh(mesh)
    params_abs = mod.abstract_params(cfg)
    batch_sh = batch_shardings(batch_abstract, mesh)
    if not shard_params:
        return StepShardings(replicated_like(params_abs, mesh),
                             None, batch_sh)
    rules = train_rules(mesh)
    params_axes = mod.param_axes(cfg)
    params_sh = tree_shardings(params_abs, params_axes, mesh, rules)
    opt_sh = None
    if optimizer_name == "gwt":
        opt_sh = gwt_state_shardings(params_abs, params_axes, mesh, rules,
                                     level, eligible=eligible, host=host,
                                     state_codec=state_codec)
    return StepShardings(params_sh, opt_sh, batch_sh)


def batch_shardings(batch_abstract: Dict[str, Any], mesh):
    """Input shardings: batch dims over DP axes, everything else replicated."""
    mesh = compat.unwrap_mesh(mesh)
    dp = _dp_axes(mesh)
    dp_size = math.prod(mesh.shape[a] for a in dp)
    out = {}
    for k, v in batch_abstract.items():
        bdim = 1 if k == "mrope_positions" else 0
        spec = [None] * len(v.shape)
        if v.shape[bdim] % dp_size == 0:
            spec[bdim] = dp if len(dp) > 1 else dp[0]
        elif v.shape[bdim] % mesh.shape["data"] == 0:
            spec[bdim] = "data"
        out[k] = NamedSharding(mesh, P(*spec))
    return out
