"""Sharded, atomic, resharding-on-restore checkpointing.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json         # tree structure, shapes, dtypes, step
        arr_000000.npy ...    # one file per leaf (full logical array)
        COMMITTED             # written LAST -> crash-safe atomicity

* ``save`` is asynchronous (daemon thread) — training continues while the
  previous step serializes; a SIGTERM handler can force a final sync save.
* ``restore`` takes an optional tree of NamedShardings and ``device_put``s
  each leaf — restoring under a *different mesh/topology than the save*
  works by construction (elastic scaling).  An optional ``ctx``
  (MeshContext) activates the target mesh around the device_puts so
  bare-spec shardings resolve on every supported JAX version.
* ``gc_keep`` prunes old committed checkpoints.

On a real multi-host pod each host writes only the shards it owns
(``arr.addressable_shards``); in this single-process container every array
is fully addressable so files hold full logical arrays — the manifest
format is host-count independent.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro import compat


class StructureMismatch(ValueError):
    """Checkpoint layout does not match the requested ``like`` tree.

    Raised (instead of silently reshaping) when leaf counts or shapes
    disagree — e.g. restoring a legacy per-leaf tuple optimizer state into
    the bucketed engine layout.  Callers catch this to run a migration
    (see ``repro.launch.train``: restore with ``engine.legacy_like`` then
    ``engine.migrate_legacy``)."""


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str, gc_keep: int = 3,
                 run_meta: Optional[dict] = None):
        """``run_meta`` (JSON-serializable) is stamped into every
        manifest under ``"run"`` — the launcher records data provenance
        there (source kind, corpus content hash, sample-order seed), so a
        resume can refuse to continue on a different corpus than the one
        the checkpoint was trained on (see ``launch/train.py``)."""
        self.dir = directory
        self.gc_keep = gc_keep
        self.run_meta = run_meta
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- helpers -----------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def committed_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "COMMITTED")):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def manifest(self, step: Optional[int] = None) -> dict:
        """The saved manifest (tree structure string, leaf shapes/dtypes) —
        lets callers inspect a checkpoint's layout before choosing a
        ``like`` tree (e.g. legacy-vs-bucketed optimizer state)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)

    def saved_run(self, step: Optional[int] = None) -> dict:
        """The ``run_meta`` dict stamped into the saved manifest ({} for
        checkpoints written before run metadata existed).  The launcher
        reads ``saved_run().get("state_codec")`` to detect codec changes
        across ``--resume`` and transcode the optimizer state."""
        return self.manifest(step).get("run") or {}

    # -- save --------------------------------------------------------------
    def _write(self, step: int, tree: Any):
        d = self._step_dir(step)
        tmp = d + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        flat, treedef = _leaf_paths(tree)
        meta = {"step": step, "treedef": str(treedef), "leaves": []}
        if self.run_meta is not None:
            meta["run"] = self.run_meta
        for i, leaf in enumerate(flat):
            arr = np.asarray(jax.device_get(leaf))
            # raw bytes + manifest dtype: robust for ml_dtypes (bf16 etc.)
            with open(os.path.join(tmp, f"arr_{i:06d}.bin"), "wb") as f:
                f.write(np.ascontiguousarray(arr).tobytes())
            meta["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(d, ignore_errors=True)
        os.rename(tmp, d)
        self._gc()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.gc_keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def save(self, step: int, tree: Any, *, blocking: bool = False,
             snapshot: bool = False):
        """Async by default; the previous async save is joined first (at
        most one in flight — bounds host memory).

        ``snapshot=True`` takes an on-device copy of every leaf before
        handing the tree to the writer thread.  Required when the caller
        donates its buffers to the next step (the pipelined train loop):
        without it the async writer would ``device_get`` arrays whose
        buffers XLA has already reused.  The copy is device-side and
        cheap; the brief ``block_until_ready`` guarantees the copies are
        materialized before the caller's next donated dispatch."""
        self.wait()
        if blocking:
            self._write(step, tree)
            return
        if snapshot:
            tree = jax.tree_util.tree_map(lambda a: a.copy(), tree)
            jax.block_until_ready(tree)
        # device_get in the caller thread is avoided: jax arrays are
        # snapshotted lazily inside the writer (they are immutable).
        self._thread = threading.Thread(
            target=self._write, args=(step, tree), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore -----------------------------------------------------------
    def restore(self, step: Optional[int], like: Any,
                shardings: Any = None, ctx: Any = None) -> Any:
        """Restore into the structure of ``like``; if ``shardings`` given,
        leaves are device_put to them (mesh may differ from save time).
        ``ctx`` (a MeshContext) makes the target mesh ambient during the
        device_puts."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        flat, treedef = _leaf_paths(like)
        if len(flat) != len(meta["leaves"]):
            raise StructureMismatch(
                f"checkpoint step {step} has {len(meta['leaves'])} leaves, "
                f"'like' tree has {len(flat)}")
        for i, (leaf, lm) in enumerate(zip(flat, meta["leaves"])):
            want_shape = tuple(getattr(leaf, "shape", lm["shape"]))
            if want_shape != tuple(lm["shape"]):
                raise StructureMismatch(
                    f"leaf {i}: checkpoint shape {tuple(lm['shape'])} != "
                    f"requested {want_shape}")
        sflat = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(flat))
        out = []
        with compat.use_mesh(compat.unwrap_mesh(ctx)):
            for i, (leaf, sh, lm) in enumerate(zip(flat, sflat,
                                                   meta["leaves"])):
                import jax.numpy as jnp
                dt = jnp.dtype(lm["dtype"])
                with open(os.path.join(d, f"arr_{i:06d}.bin"), "rb") as f:
                    arr = np.frombuffer(f.read(), dtype=dt).reshape(lm["shape"])
                want = jnp.dtype(getattr(leaf, "dtype", arr.dtype))
                if want != arr.dtype:
                    arr = arr.astype(want)
                out.append(jax.device_put(arr, sh) if sh is not None
                           else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step

    def restore_params(self, step: Optional[int], like_params: Any,
                       shardings: Any = None, ctx: Any = None) -> Any:
        """Restore ONLY the model parameters from a training checkpoint —
        the serving load path (``repro.serve.Engine.from_checkpoint``).

        Training saves ``{"opt": <optimizer state>, "params": <params>}``;
        dict keys flatten in sorted order ("opt" < "params"), so the
        params leaves are exactly the TRAILING leaves of the manifest.
        Restoring by trailing offset skips deserializing the optimizer
        state (2-3× the param bytes under the f32 codec) and works
        unchanged on a checkpoint holding a bare params tree (offset 0).
        Trailing-leaf shapes are validated against ``like_params``;
        disagreement raises :class:`StructureMismatch` rather than
        serving silently wrong weights."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        flat, treedef = _leaf_paths(like_params)
        offset = len(meta["leaves"]) - len(flat)
        if offset < 0:
            raise StructureMismatch(
                f"checkpoint step {step} has {len(meta['leaves'])} leaves "
                f"but the params tree alone has {len(flat)}")
        leaves_meta = meta["leaves"][offset:]
        for i, (leaf, m) in enumerate(zip(flat, leaves_meta)):
            want_shape = tuple(getattr(leaf, "shape", m["shape"]))
            if want_shape != tuple(m["shape"]):
                raise StructureMismatch(
                    f"params leaf {i} (manifest leaf {offset + i}): "
                    f"checkpoint shape {tuple(m['shape'])} != requested "
                    f"{want_shape} — is this checkpoint from the same "
                    f"arch config?")
        sflat = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(flat))
        out = []
        with compat.use_mesh(compat.unwrap_mesh(ctx)):
            for i, (leaf, sh, m) in enumerate(zip(flat, sflat, leaves_meta)):
                import jax.numpy as jnp
                dt = jnp.dtype(m["dtype"])
                with open(os.path.join(
                        d, f"arr_{offset + i:06d}.bin"), "rb") as f:
                    arr = np.frombuffer(f.read(), dtype=dt).reshape(m["shape"])
                want = jnp.dtype(getattr(leaf, "dtype", arr.dtype))
                if want != arr.dtype:
                    arr = arr.astype(want)
                out.append(jax.device_put(arr, sh) if sh is not None
                           else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
