"""Low-rank baselines the paper compares against (Table II/III):

* **GaLore** (Zhao et al. 2024): SVD projection of gradients; Adam states in
  the rank-r subspace; projector refreshed every ``update_gap`` steps.
* **APOLLO** (Zhu et al. 2024): SVD-free — random projection + channel-wise
  gradient scaling; full-rank update direction.
* **Fira** (Chen et al. 2024): GaLore + scaled full-rank residual + NL.
* **AdaRankGrad** (arXiv 2410.17881): per-leaf rank adapted from the gradient
  spectrum's energy decay — the projector keeps only the top-k singular
  directions covering a ``tau`` fraction of squared energy, with k monotone
  non-increasing over refreshes; moments are rotated into each new basis.
* **RSO** (arXiv 2502.07222): seeded randomized-subspace projection — an
  orthonormalized Gaussian projector resampled every ``update_gap`` steps
  (SVD-free), with the same moment rotation across resamples.

All share the per-leaf routing of GWT: eligible ≥2-D weights get compressed
states, the rest run plain Adam.  ``rank_frac`` (e.g. 1/4, 1/8) matches the
paper's GaLore-1/4 / GaLore-1/8 naming: ``r = rank_frac · min(m, n)``.

Declared as rules over the shared bucketed engine: same-shaped leaves stack
into one ``(L, m, n)`` bucket whose update (including the ``lax.cond``-gated
SVD refresh) is traced once inside a ``lax.scan`` body.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import limiter
from repro.optim import engine, hosts as hosts_lib
from repro.optim.base import Optimizer, default_eligible
from repro.optim.schedules import Schedule, constant


def _norm_lr(lr):
    return constant(lr) if isinstance(lr, (int, float)) else lr


def _rank(p, rank, rank_frac):
    if rank is not None:
        return max(1, min(rank, min(p.shape[-2:])))
    return max(1, int(min(p.shape[-2:]) * rank_frac))


def _project_left(p) -> bool:
    """GaLore projects the smaller side: left if rows <= cols."""
    return p.shape[-2] <= p.shape[-1]


def _svd_projector(g, r, left):
    g32 = g.astype(jnp.float32)
    u, _, vt = jnp.linalg.svd(g32, full_matrices=False)
    return u[..., :, :r] if left else jnp.swapaxes(vt, -1, -2)[..., :, :r]


def _rand_projector(key, p, r, left, dtype=jnp.float32):
    m = p.shape[-2] if left else p.shape[-1]
    shape = p.shape[:-2] + (m, r)
    return jax.random.normal(key, shape, dtype) / jnp.sqrt(r).astype(dtype)


def _down(g, proj, left):
    """Full grad -> subspace: (r×n) = Pᵀ G  or  (m×r) = G P."""
    pt = jnp.swapaxes(proj, -1, -2)
    return pt @ g.astype(proj.dtype) if left else g.astype(proj.dtype) @ proj


def _up(rlow, proj, left):
    return proj @ rlow if left else rlow @ jnp.swapaxes(proj, -1, -2)


def _orth_rand_projector(key, p, r, left, dtype=jnp.float32):
    """Orthonormalized Gaussian projector: QR of an (…, m, r) normal draw.

    m ≥ r always holds (r ≤ min(m, n) via ``_rank``), so reduced QR yields
    exactly orthonormal columns: PᵀP = I_r.
    """
    m = p.shape[-2] if left else p.shape[-1]
    shape = tuple(p.shape[:-2]) + (m, r)
    q, _ = jnp.linalg.qr(jax.random.normal(key, shape, dtype))
    return q


def _effective_rank(s, tau, r_max):
    """#singular values whose squared energy reaches a ``tau`` fraction.

    ``s``: (…, k) singular values, descending.  Returns a float32 scalar in
    [1, r_max]; batch dims collapse via max (one rank per leaf, so the masked
    projector stays a single static-shape buffer).
    """
    e = s.astype(jnp.float32) ** 2
    c = jnp.cumsum(e, axis=-1)
    tot = jnp.maximum(c[..., -1:], 1e-30)
    k = jnp.sum((c / tot) < tau, axis=-1) + 1
    return jnp.max(jnp.clip(k, 1, r_max)).astype(jnp.float32)


def _rotate_moments(hstate, proj_old, proj_new, left):
    """Carry Adam moments across a basis change via T = P_newᵀ P_old.

    m' = T m (left) rotates the first moment exactly; v' = (T∘T) v is the
    standard nonnegative approximation for the second moment.
    """
    t = jnp.swapaxes(proj_new, -1, -2) @ proj_old
    if left:
        m = t @ hstate["m"].astype(jnp.float32)
        v = (t * t) @ hstate["v"].astype(jnp.float32)
    else:
        m = hstate["m"].astype(jnp.float32) @ jnp.swapaxes(t, -1, -2)
        v = hstate["v"].astype(jnp.float32) @ jnp.swapaxes(t * t, -1, -2)
    return {"m": m.astype(hstate["m"].dtype), "v": v.astype(hstate["v"].dtype)}


def _make_lowrank(name: str,
                  lr, rank, rank_frac, alpha, update_gap,
                  eligible, use_limiter_flag, gamma,
                  seed: int, state_dtype,
                  b1=0.9, b2=0.999, eps=1e-6,
                  bucketed: bool = True, state_codec="f32") -> Optimizer:
    lr = _norm_lr(lr)
    host = hosts_lib.adam(b1, b2, eps, state_dtype)
    elig = eligible or default_eligible

    def leaf_is_lowrank(path, p):
        return elig(path, p) and p.ndim >= 2 and min(p.shape[-2:]) >= 2

    # -- plain rule: host Adam on the full tensor ---------------------------
    def plain_update(g, p, state, step, leaf_id):
        precond, _, lr_mult, hstate = host.update(g, state["host"], step)
        q = p.astype(jnp.float32) - (lr(step) * lr_mult) * precond.astype(jnp.float32)
        return q.astype(p.dtype), {"host": hstate}

    plain_rule = engine.LeafRule(
        kind="plain", init=lambda p: {"host": host.init(p)},
        update=plain_update, slots={"host": host.slots})

    # -- low-rank rule ------------------------------------------------------
    def lowrank_init(p):
        r = _rank(p, rank, rank_frac)
        left = _project_left(p)
        m = p.shape[-2] if left else p.shape[-1]
        low_shape = (tuple(p.shape[:-2]) + (r, p.shape[-1])) if left \
            else (tuple(p.shape[:-2]) + (p.shape[-2], r))
        st = {"host": host.init(jax.ShapeDtypeStruct(low_shape, state_dtype)),
              "proj": jnp.zeros(tuple(p.shape[:-2]) + (m, r), jnp.float32)}
        if name in ("fira", "apollo"):
            st["prev_norm"] = jnp.zeros((), jnp.float32)
        return st

    def lowrank_update(g, p, state, step, leaf_id):
        out = dict(state)
        lr_t = lr(step)
        r = _rank(p, rank, rank_frac)
        left = _project_left(p)
        refresh = (step % update_gap) == 0
        if name == "apollo":
            # deterministic per-(leaf, epoch) random projector — O(mnr)
            key = jax.random.fold_in(jax.random.key(seed + leaf_id),
                                     step // update_gap)
            proj_new_fn = lambda: _rand_projector(key, p, r, left)
        else:
            proj_new_fn = lambda: _svd_projector(g, r, left)
        # lax.cond: the O(m n²) SVD only *executes* on refresh steps.
        proj = jax.lax.cond(refresh, proj_new_fn,
                            lambda: state["proj"].astype(jnp.float32))
        out["proj"] = proj

        rlow = _down(g, proj, left)
        rtilde, _, lr_mult, out["host"] = host.update(rlow, state["host"], step)

        if name == "galore":
            delta = _up(rtilde, proj, left)
        elif name == "fira":
            main = _up(rtilde, proj, left)
            resid = g.astype(jnp.float32) - _up(rlow, proj, left)
            phi = (jnp.linalg.norm(rtilde) /
                   jnp.maximum(jnp.linalg.norm(rlow), 1e-12))
            delta = main + phi * resid
        else:  # apollo: channel-wise scaling of the FULL-RANK gradient
            axis = -2 if left else -1  # norm over the projected dim
            snum = jnp.linalg.norm(rtilde, axis=axis, keepdims=True)
            sden = jnp.maximum(jnp.linalg.norm(rlow, axis=axis, keepdims=True), 1e-12)
            s = snum / sden  # (1,n) if left else (m,1): channel-wise
            delta = g.astype(jnp.float32) * s
            lr_mult = jnp.asarray(1.0, jnp.float32)

        if use_limiter_flag and "prev_norm" in out:
            delta, out["prev_norm"] = limiter.limit(delta, state["prev_norm"],
                                                    gamma)

        q = p.astype(jnp.float32) - (lr_t * lr_mult * alpha) * delta.astype(jnp.float32)
        return q.astype(p.dtype), out

    # projector + limiter memory stay exact (the projector is the subspace
    # itself; re-quantizing it would rotate the moments' basis) — only the
    # host moments in the rank-r subspace go through the codec.
    lowrank_slots = {"host": host.slots, "proj": False}
    if name in ("fira", "apollo"):
        lowrank_slots["prev_norm"] = False
    lowrank_rule = engine.LeafRule(kind=name, init=lowrank_init,
                                   update=lowrank_update,
                                   slots=lowrank_slots)

    return engine.build(
        lambda path, leaf: (lowrank_rule if leaf_is_lowrank(path, leaf)
                            else plain_rule),
        bucketed=bucketed, codec=state_codec)


def _make_adaptive(name: str,
                   lr, rank, rank_frac, alpha, update_gap, tau,
                   seed: int, eligible, state_dtype,
                   b1=0.9, b2=0.999, eps=1e-6,
                   bucketed: bool = True, state_codec="f32") -> Optimizer:
    """Template for the two adaptive-subspace rules (adarankgrad / rso).

    Both refresh the projector every ``update_gap`` steps and rotate the
    host moments into the new basis (``_rotate_moments``) instead of letting
    them go stale; they differ only in where the new basis comes from —
    gradient SVD + energy-masked columns vs a seeded orthonormal random draw.
    """
    lr = _norm_lr(lr)
    host = hosts_lib.adam(b1, b2, eps, state_dtype)
    elig = eligible or default_eligible

    def leaf_is_lowrank(path, p):
        return elig(path, p) and p.ndim >= 2 and min(p.shape[-2:]) >= 2

    def plain_update(g, p, state, step, leaf_id):
        precond, _, lr_mult, hstate = host.update(g, state["host"], step)
        q = p.astype(jnp.float32) - (lr(step) * lr_mult) * precond.astype(jnp.float32)
        return q.astype(p.dtype), {"host": hstate}

    plain_rule = engine.LeafRule(
        kind="plain", init=lambda p: {"host": host.init(p)},
        update=plain_update, slots={"host": host.slots})

    def adaptive_init(p):
        r = _rank(p, rank, rank_frac)  # r_max for adarankgrad
        left = _project_left(p)
        m = p.shape[-2] if left else p.shape[-1]
        low_shape = (tuple(p.shape[:-2]) + (r, p.shape[-1])) if left \
            else (tuple(p.shape[:-2]) + (p.shape[-2], r))
        st = {"host": host.init(jax.ShapeDtypeStruct(low_shape, state_dtype)),
              "proj": jnp.zeros(tuple(p.shape[:-2]) + (m, r), jnp.float32)}
        if name == "adarankgrad":
            st["rank"] = jnp.asarray(float(r), jnp.float32)
        return st

    def adaptive_update(g, p, state, step, leaf_id):
        out = dict(state)
        r = _rank(p, rank, rank_frac)
        left = _project_left(p)
        refresh = (step % update_gap) == 0

        if name == "adarankgrad":
            def proj_rank_new():
                g32 = g.astype(jnp.float32)
                u, s, vt = jnp.linalg.svd(g32, full_matrices=False)
                basis = u[..., :, :r] if left \
                    else jnp.swapaxes(vt, -1, -2)[..., :, :r]
                # monotone non-increasing rank schedule: never exceed the
                # previous effective rank (init = r_max).
                k = jnp.minimum(_effective_rank(s, tau, r), state["rank"])
                mask = (jnp.arange(r) < k).astype(jnp.float32)
                return basis * mask, k

            def proj_rank_old():
                return state["proj"].astype(jnp.float32), state["rank"]

            proj, out["rank"] = jax.lax.cond(refresh, proj_rank_new,
                                             proj_rank_old)
        else:  # rso: deterministic per-(leaf, epoch) orthonormal projector
            key = jax.random.fold_in(jax.random.key(seed + leaf_id),
                                     step // update_gap)
            proj = jax.lax.cond(refresh,
                                lambda: _orth_rand_projector(key, p, r, left),
                                lambda: state["proj"].astype(jnp.float32))
        out["proj"] = proj

        # rotate moments into the refreshed basis (zeros at step 0 stay
        # zeros: proj_old is the zero init, so T = 0 on the first refresh).
        hstate = jax.lax.cond(
            refresh,
            lambda: _rotate_moments(state["host"],
                                    state["proj"].astype(jnp.float32),
                                    proj, left),
            lambda: state["host"])

        rlow = _down(g, proj, left)
        rtilde, _, lr_mult, out["host"] = host.update(rlow, hstate, step)
        delta = _up(rtilde, proj, left)
        q = p.astype(jnp.float32) - (lr(step) * lr_mult * alpha) * delta.astype(jnp.float32)
        return q.astype(p.dtype), out

    adaptive_slots = {"host": host.slots, "proj": False}
    if name == "adarankgrad":
        adaptive_slots["rank"] = False
    adaptive_rule = engine.LeafRule(kind=name, init=adaptive_init,
                                    update=adaptive_update,
                                    slots=adaptive_slots)

    return engine.build(
        lambda path, leaf: (adaptive_rule if leaf_is_lowrank(path, leaf)
                            else plain_rule),
        bucketed=bucketed, codec=state_codec)


def galore(lr, rank: Optional[int] = None, rank_frac: float = 0.25,
           alpha: float = 0.25, update_gap: int = 200,
           eligible: Callable = None, state_dtype=jnp.float32,
           bucketed: bool = True, state_codec="f32") -> Optimizer:
    return _make_lowrank("galore", lr, rank, rank_frac, alpha, update_gap,
                         eligible, False, limiter.DEFAULT_GAMMA, 0,
                         state_dtype, bucketed=bucketed,
                         state_codec=state_codec)


def apollo(lr, rank: Optional[int] = None, rank_frac: float = 0.25,
           alpha: float = 1.0, update_gap: int = 200, seed: int = 0,
           eligible: Callable = None, state_dtype=jnp.float32,
           bucketed: bool = True, state_codec="f32") -> Optimizer:
    return _make_lowrank("apollo", lr, rank, rank_frac, alpha, update_gap,
                         eligible, True, limiter.DEFAULT_GAMMA, seed,
                         state_dtype, bucketed=bucketed,
                         state_codec=state_codec)


def fira(lr, rank: Optional[int] = None, rank_frac: float = 0.25,
         alpha: float = 0.25, update_gap: int = 200,
         eligible: Callable = None, state_dtype=jnp.float32,
         bucketed: bool = True, state_codec="f32") -> Optimizer:
    return _make_lowrank("fira", lr, rank, rank_frac, alpha, update_gap,
                         eligible, True, limiter.DEFAULT_GAMMA, 0,
                         state_dtype, bucketed=bucketed,
                         state_codec=state_codec)


def adarankgrad(lr, rank: Optional[int] = None, rank_frac: float = 0.25,
                alpha: float = 0.25, update_gap: int = 200, tau: float = 0.9,
                eligible: Callable = None, state_dtype=jnp.float32,
                bucketed: bool = True, state_codec="f32") -> Optimizer:
    """AdaRankGrad (arXiv 2410.17881): adaptive per-leaf rank from the
    gradient spectrum's energy decay, re-projected on a step schedule.

    ``rank``/``rank_frac`` set the rank *ceiling* r_max (static buffer
    shape); the live rank is a traced state scalar, monotone non-increasing
    across refreshes, realized as column masking of the projector.
    """
    return _make_adaptive("adarankgrad", lr, rank, rank_frac, alpha,
                          update_gap, tau, 0, eligible, state_dtype,
                          bucketed=bucketed, state_codec=state_codec)


def rso(lr, rank: Optional[int] = None, rank_frac: float = 0.25,
        alpha: float = 0.25, update_gap: int = 200, seed: int = 0,
        eligible: Callable = None, state_dtype=jnp.float32,
        bucketed: bool = True, state_codec="f32") -> Optimizer:
    """RSO (arXiv 2502.07222): seeded randomized-subspace projection —
    orthonormal Gaussian projector resampled every ``update_gap`` steps,
    SVD-free, moments rotated across resamples."""
    return _make_adaptive("rso", lr, rank, rank_frac, alpha, update_gap,
                          0.0, seed, eligible, state_dtype,
                          bucketed=bucketed, state_codec=state_codec)
