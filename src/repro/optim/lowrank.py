"""Low-rank baselines the paper compares against (Table II/III):

* **GaLore** (Zhao et al. 2024): SVD projection of gradients; Adam states in
  the rank-r subspace; projector refreshed every ``update_gap`` steps.
* **APOLLO** (Zhu et al. 2024): SVD-free — random projection + channel-wise
  gradient scaling; full-rank update direction.
* **Fira** (Chen et al. 2024): GaLore + scaled full-rank residual + NL.

All share the per-leaf routing of GWT: eligible ≥2-D weights get compressed
states, the rest run plain Adam.  ``rank_frac`` (e.g. 1/4, 1/8) matches the
paper's GaLore-1/4 / GaLore-1/8 naming: ``r = rank_frac · min(m, n)``.

Declared as rules over the shared bucketed engine: same-shaped leaves stack
into one ``(L, m, n)`` bucket whose update (including the ``lax.cond``-gated
SVD refresh) is traced once inside a ``lax.scan`` body.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import limiter
from repro.optim import engine, hosts as hosts_lib
from repro.optim.base import Optimizer, default_eligible
from repro.optim.schedules import Schedule, constant


def _norm_lr(lr):
    return constant(lr) if isinstance(lr, (int, float)) else lr


def _rank(p, rank, rank_frac):
    if rank is not None:
        return max(1, min(rank, min(p.shape[-2:])))
    return max(1, int(min(p.shape[-2:]) * rank_frac))


def _project_left(p) -> bool:
    """GaLore projects the smaller side: left if rows <= cols."""
    return p.shape[-2] <= p.shape[-1]


def _svd_projector(g, r, left):
    g32 = g.astype(jnp.float32)
    u, _, vt = jnp.linalg.svd(g32, full_matrices=False)
    return u[..., :, :r] if left else jnp.swapaxes(vt, -1, -2)[..., :, :r]


def _rand_projector(key, p, r, left, dtype=jnp.float32):
    m = p.shape[-2] if left else p.shape[-1]
    shape = p.shape[:-2] + (m, r)
    return jax.random.normal(key, shape, dtype) / jnp.sqrt(r).astype(dtype)


def _down(g, proj, left):
    """Full grad -> subspace: (r×n) = Pᵀ G  or  (m×r) = G P."""
    pt = jnp.swapaxes(proj, -1, -2)
    return pt @ g.astype(proj.dtype) if left else g.astype(proj.dtype) @ proj


def _up(rlow, proj, left):
    return proj @ rlow if left else rlow @ jnp.swapaxes(proj, -1, -2)


def _make_lowrank(name: str,
                  lr, rank, rank_frac, alpha, update_gap,
                  eligible, use_limiter_flag, gamma,
                  seed: int, state_dtype,
                  b1=0.9, b2=0.999, eps=1e-6,
                  bucketed: bool = True, state_codec="f32") -> Optimizer:
    lr = _norm_lr(lr)
    host = hosts_lib.adam(b1, b2, eps, state_dtype)
    elig = eligible or default_eligible

    def leaf_is_lowrank(path, p):
        return elig(path, p) and p.ndim >= 2 and min(p.shape[-2:]) >= 2

    # -- plain rule: host Adam on the full tensor ---------------------------
    def plain_update(g, p, state, step, leaf_id):
        precond, _, lr_mult, hstate = host.update(g, state["host"], step)
        q = p.astype(jnp.float32) - (lr(step) * lr_mult) * precond.astype(jnp.float32)
        return q.astype(p.dtype), {"host": hstate}

    plain_rule = engine.LeafRule(
        kind="plain", init=lambda p: {"host": host.init(p)},
        update=plain_update, slots={"host": host.slots})

    # -- low-rank rule ------------------------------------------------------
    def lowrank_init(p):
        r = _rank(p, rank, rank_frac)
        left = _project_left(p)
        m = p.shape[-2] if left else p.shape[-1]
        low_shape = (tuple(p.shape[:-2]) + (r, p.shape[-1])) if left \
            else (tuple(p.shape[:-2]) + (p.shape[-2], r))
        st = {"host": host.init(jax.ShapeDtypeStruct(low_shape, state_dtype)),
              "proj": jnp.zeros(tuple(p.shape[:-2]) + (m, r), jnp.float32)}
        if name in ("fira", "apollo"):
            st["prev_norm"] = jnp.zeros((), jnp.float32)
        return st

    def lowrank_update(g, p, state, step, leaf_id):
        out = dict(state)
        lr_t = lr(step)
        r = _rank(p, rank, rank_frac)
        left = _project_left(p)
        refresh = (step % update_gap) == 0
        if name == "apollo":
            # deterministic per-(leaf, epoch) random projector — O(mnr)
            key = jax.random.fold_in(jax.random.key(seed + leaf_id),
                                     step // update_gap)
            proj_new_fn = lambda: _rand_projector(key, p, r, left)
        else:
            proj_new_fn = lambda: _svd_projector(g, r, left)
        # lax.cond: the O(m n²) SVD only *executes* on refresh steps.
        proj = jax.lax.cond(refresh, proj_new_fn,
                            lambda: state["proj"].astype(jnp.float32))
        out["proj"] = proj

        rlow = _down(g, proj, left)
        rtilde, _, lr_mult, out["host"] = host.update(rlow, state["host"], step)

        if name == "galore":
            delta = _up(rtilde, proj, left)
        elif name == "fira":
            main = _up(rtilde, proj, left)
            resid = g.astype(jnp.float32) - _up(rlow, proj, left)
            phi = (jnp.linalg.norm(rtilde) /
                   jnp.maximum(jnp.linalg.norm(rlow), 1e-12))
            delta = main + phi * resid
        else:  # apollo: channel-wise scaling of the FULL-RANK gradient
            axis = -2 if left else -1  # norm over the projected dim
            snum = jnp.linalg.norm(rtilde, axis=axis, keepdims=True)
            sden = jnp.maximum(jnp.linalg.norm(rlow, axis=axis, keepdims=True), 1e-12)
            s = snum / sden  # (1,n) if left else (m,1): channel-wise
            delta = g.astype(jnp.float32) * s
            lr_mult = jnp.asarray(1.0, jnp.float32)

        if use_limiter_flag and "prev_norm" in out:
            delta, out["prev_norm"] = limiter.limit(delta, state["prev_norm"],
                                                    gamma)

        q = p.astype(jnp.float32) - (lr_t * lr_mult * alpha) * delta.astype(jnp.float32)
        return q.astype(p.dtype), out

    # projector + limiter memory stay exact (the projector is the subspace
    # itself; re-quantizing it would rotate the moments' basis) — only the
    # host moments in the rank-r subspace go through the codec.
    lowrank_slots = {"host": host.slots, "proj": False}
    if name in ("fira", "apollo"):
        lowrank_slots["prev_norm"] = False
    lowrank_rule = engine.LeafRule(kind=name, init=lowrank_init,
                                   update=lowrank_update,
                                   slots=lowrank_slots)

    return engine.build(
        lambda path, leaf: (lowrank_rule if leaf_is_lowrank(path, leaf)
                            else plain_rule),
        bucketed=bucketed, codec=state_codec)


def galore(lr, rank: Optional[int] = None, rank_frac: float = 0.25,
           alpha: float = 0.25, update_gap: int = 200,
           eligible: Callable = None, state_dtype=jnp.float32,
           bucketed: bool = True, state_codec="f32") -> Optimizer:
    return _make_lowrank("galore", lr, rank, rank_frac, alpha, update_gap,
                         eligible, False, limiter.DEFAULT_GAMMA, 0,
                         state_dtype, bucketed=bucketed,
                         state_codec=state_codec)


def apollo(lr, rank: Optional[int] = None, rank_frac: float = 0.25,
           alpha: float = 1.0, update_gap: int = 200, seed: int = 0,
           eligible: Callable = None, state_dtype=jnp.float32,
           bucketed: bool = True, state_codec="f32") -> Optimizer:
    return _make_lowrank("apollo", lr, rank, rank_frac, alpha, update_gap,
                         eligible, True, limiter.DEFAULT_GAMMA, seed,
                         state_dtype, bucketed=bucketed,
                         state_codec=state_codec)


def fira(lr, rank: Optional[int] = None, rank_frac: float = 0.25,
         alpha: float = 0.25, update_gap: int = 200,
         eligible: Callable = None, state_dtype=jnp.float32,
         bucketed: bool = True, state_codec="f32") -> Optimizer:
    return _make_lowrank("fira", lr, rank, rank_frac, alpha, update_gap,
                         eligible, True, limiter.DEFAULT_GAMMA, 0,
                         state_dtype, bucketed=bucketed,
                         state_codec=state_codec)
