"""Optimizer registry. ``make('gwt', lr=..., level=3)`` etc.

Every registered optimizer is a thin rule declaration over the shared
bucketed engine (``repro.optim.engine``); pass ``bucketed=False`` to any
constructor for the unrolled per-leaf reference semantics.
"""

from repro.optim.base import Optimizer, default_eligible, global_norm
from repro.optim import engine, hosts, schedules
from repro.optim.standard import adam, adam_mini, muon, sgd, from_host
from repro.optim.lowrank import galore, apollo, fira, adarankgrad, rso


def make(name: str, **kw) -> Optimizer:
    from repro.core.gwt import gwt  # local import to avoid cycle
    registry = {
        "adam": adam, "adam_mini": adam_mini, "muon": muon, "sgd": sgd,
        "galore": galore, "apollo": apollo, "fira": fira, "gwt": gwt,
        "adarankgrad": adarankgrad, "rso": rso,
    }
    if name not in registry:
        raise ValueError(f"unknown optimizer {name!r}; choices: {sorted(registry)}")
    return registry[name](**kw)


__all__ = ["Optimizer", "make", "adam", "adam_mini", "muon", "sgd", "galore",
           "apollo", "fira", "adarankgrad", "rso", "from_host",
           "default_eligible", "global_norm", "engine", "hosts", "schedules"]
