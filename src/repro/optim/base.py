"""Optimizer substrate: a minimal self-contained optax-style interface.

``Optimizer.init(params) -> state``;
``Optimizer.update(grads, state, params) -> (new_params, new_state)``.

Leaf addressing uses '/'-joined path strings from
``jax.tree_util.tree_flatten_with_path`` so that module-wise policies
(the paper's "GWT on attention+MLP, Adam elsewhere") are name-driven and
architecture-agnostic.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Any
Grads = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], OptState]
    update: Callable[[Grads, OptState, Params], Tuple[Params, OptState]]
    # Engine-built optimizers (repro.optim.engine) attach their Engine here:
    # exposes plan()/legacy_like()/migrate_legacy() for checkpoint migration
    # and per-bucket sharding.  None for hand-rolled optimizers.
    engine: Any = None
    # Optional tapped channel: ``(grads, state, params) -> (new_params,
    # new_state, taps)`` where ``taps`` is a flat dict of f32 scalars
    # ("<bucket>/<metric>") computed in the same trace as the update
    # (repro.optim.engine attaches it; DESIGN.md §12).  ``update`` stays
    # the tap-free graph, so not calling this costs nothing.
    tapped_update: Any = None


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def map_with_path(fn, tree, *rest):
    """tree_map with a '/'-joined path string as first arg."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, *leaves: fn(path_str(kp), *leaves), tree, *rest)


def flatten_with_paths(tree):
    """Returns ``(paths, leaves, treedef)`` with '/'-joined path strings.

    Per-leaf optimizers store their states as a *tuple aligned with this
    flattening order* — sidestepping pytree-structure mismatches between
    param trees (array leaves) and state trees (dict-of-arrays leaves).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [path_str(kp) for kp, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


# Default deny-list: parameters that never get subspace compression
# (embeddings, output head, norms, biases, 1-D tensors).  Matches the
# paper's module-wise strategy ("attention and MLP modules", the rest on
# plain Adam).  Recurrent-dynamics kernels are also denied: the SSM
# selective-scan projections (``x_proj`` packs [dt|B|C] channels of
# unrelated scales, ``dt_proj`` feeds a softplus time-step) and the
# xLSTM gate kernels (``w_igate``/``w_fgate`` parameterize exponential
# gates) couple heterogeneous dynamics along the transformed axis —
# outside the paper's attention/MLP scope and numerically brittle under
# a shared wavelet/low-rank basis.
_DENY_SUBSTRINGS = ("embed", "lm_head", "norm", "scale", "bias", "pos_",
                    "router", "a_log", "dt_bias", "conv",
                    "x_proj", "dt_proj", "igate", "fgate")

# Exact last-path-segment denials: the sLSTM recurrent kernel ``r``
# (H, dh, 4·dh) stacks four gate blocks of a state-to-state recurrence.
_DENY_SEGMENTS = ("r",)


def default_eligible(path: str, leaf: jax.Array) -> bool:
    """True if ``leaf`` should get subspace/wavelet-compressed states.

    Pure name/rank policy — axis-divisibility by the transform block
    (``2^level``) is the caller's job (``repro.core.gwt._leaf_mode``), so
    eligibility and mode selection cannot disagree.
    """
    lname = path.lower()
    if any(s in lname for s in _DENY_SUBSTRINGS):
        return False
    if lname.rsplit("/", 1)[-1] in _DENY_SEGMENTS:
        return False
    return leaf.ndim >= 2


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
