"""LR schedules. Paper: 10% linear warmup + cosine annealing to 10% of peak."""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, total_steps: int, warmup_frac: float = 0.1,
                  final_frac: float = 0.1) -> Schedule:
    warmup_steps = max(1, int(total_steps * warmup_frac))

    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / warmup_steps
        prog = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)

    return sched
