"""Host optimizers operating on a single array — the "memory-intensive
optimizer" slot of the paper's Algorithm 1 (Adam by default; Adam-mini and
MUON per Fig. 4 "GWT is optimizer-agnostic").

Interface::

    host.init(arr)                 -> state pytree (shaped like the compressed rep)
    host.update(g, state, step)    -> (precond_update, detail_scale, lr_mult, state)

* ``precond_update``: the preconditioned update of the (possibly compressed)
  gradient ``g`` — e.g. Adam's ``M/(√V+ε)`` (bias correction folded into
  ``lr_mult`` exactly as Algorithm 1's ``η_t``).
* ``detail_scale``: the diagonal preconditioner to apply to wavelet *detail*
  bands (paper: ``1/(√V^R+ε)``), or ``None`` when the host has no diagonal
  preconditioner (MUON — details pass through unscaled; the paper leaves the
  non-Adam detail path unspecified, see DESIGN.md §2).
* ``lr_mult``: per-step scalar folded into the learning rate.

States are kept in ``state_dtype`` (default f32); math in f32.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Host(NamedTuple):
    init: Callable[[jax.Array], Any]
    update: Callable[[jax.Array, Any, jax.Array], Tuple[jax.Array, Optional[jax.Array], jax.Array, Any]]
    name: str = "host"
    # moment-slot mask mirroring the state structure (True = the state
    # codec may store this array blocked-quantized); see optim/codec.py
    slots: Any = None


def _f32(x):
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Adam (Kingma & Ba) — Algorithm 1's default host.
# ---------------------------------------------------------------------------

def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-6,
         state_dtype=jnp.float32) -> Host:
    def init(arr):
        z = jnp.zeros(arr.shape, state_dtype)
        return {"m": z, "v": z}

    def update(g, state, step):
        g32 = _f32(g)
        m = b1 * _f32(state["m"]) + (1 - b1) * g32
        v = b2 * _f32(state["v"]) + (1 - b2) * g32 * g32
        denom = jnp.sqrt(v) + eps
        precond = m / denom
        t = step.astype(jnp.float32) + 1.0
        lr_mult = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)
        new_state = {"m": m.astype(state_dtype), "v": v.astype(state_dtype)}
        return precond, 1.0 / denom, lr_mult, new_state

    return Host(init, update, "adam", slots={"m": True, "v": True})


# ---------------------------------------------------------------------------
# Adam-mini (Zhang et al. 2024): one second-moment per block.  For matmul
# weights we use one ``v`` per output row (neuron/head granularity) — the
# paper's LM partition collapsed to the row level.  Halves Adam's state.
# ---------------------------------------------------------------------------

def adam_mini(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-6,
              state_dtype=jnp.float32) -> Host:
    def init(arr):
        m = jnp.zeros(arr.shape, state_dtype)
        if arr.ndim >= 2:
            v = jnp.zeros(arr.shape[:-1] + (1,), state_dtype)
        else:
            v = jnp.zeros((), state_dtype)
        return {"m": m, "v": v}

    def update(g, state, step):
        g32 = _f32(g)
        m = b1 * _f32(state["m"]) + (1 - b1) * g32
        gsq = jnp.mean(g32 * g32, axis=-1, keepdims=True) if g32.ndim >= 2 \
            else jnp.mean(g32 * g32)
        v = b2 * _f32(state["v"]) + (1 - b2) * gsq
        denom = jnp.sqrt(v) + eps
        precond = m / denom
        t = step.astype(jnp.float32) + 1.0
        lr_mult = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)
        new_state = {"m": m.astype(state_dtype), "v": v.astype(state_dtype)}
        return precond, 1.0 / denom, lr_mult, new_state

    return Host(init, update, "adam_mini", slots={"m": True, "v": True})


# ---------------------------------------------------------------------------
# MUON (Liu et al. 2025): momentum + Newton-Schulz orthogonalization.
# Momentum-only state (half of Adam).  2-D (or batched 2-D) arrays only —
# callers fall back to Adam elsewhere.
# ---------------------------------------------------------------------------

_NS_COEFFS = (3.4445, -4.7750, 2.0315)


def newton_schulz(m: jax.Array, steps: int = 5) -> jax.Array:
    """Quintic Newton-Schulz iteration orthogonalizing the last two dims."""
    a, b, c = _NS_COEFFS
    x = _f32(m)
    transpose = x.shape[-2] > x.shape[-1]
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    x = x / (jnp.linalg.norm(x, axis=(-2, -1), keepdims=True) + 1e-7)

    def body(x, _):
        xxt = x @ jnp.swapaxes(x, -1, -2)
        x = a * x + (b * xxt + c * (xxt @ xxt)) @ x
        return x, None

    x, _ = jax.lax.scan(body, x, None, length=steps)
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    return x


def muon(beta: float = 0.95, ns_steps: int = 5, nesterov: bool = True,
         state_dtype=jnp.float32) -> Host:
    def init(arr):
        return {"m": jnp.zeros(arr.shape, state_dtype)}

    def update(g, state, step):
        g32 = _f32(g)
        m = beta * _f32(state["m"]) + g32
        eff = g32 + beta * m if nesterov else m
        o = newton_schulz(eff, ns_steps)
        # RMS-matching scale (Muon convention): sqrt(max(1, rows/cols)).
        rows, cols = o.shape[-2], o.shape[-1]
        o = o * jnp.sqrt(jnp.maximum(1.0, rows / cols))
        return o, None, jnp.asarray(1.0, jnp.float32), {"m": m.astype(state_dtype)}

    return Host(init, update, "muon", slots={"m": True})


HOSTS = {"adam": adam, "adam_mini": adam_mini, "muon": muon}


def make_host(name: str, **kw) -> Host:
    if name not in HOSTS:
        raise ValueError(f"unknown host optimizer {name!r}; choices: {sorted(HOSTS)}")
    return HOSTS[name](**kw)
