"""Leaf-plan + bucketed execution engine shared by all optimizer families.

The module-wise strategy ("GWT on attention/MLP, Adam elsewhere") used to be
re-implemented as an unrolled Python loop over pytree leaves in three places
(``core/gwt.py``, ``optim/standard.py``, ``optim/lowrank.py``).  That bloats
the jitted trace linearly with layer count and invokes the fused kernel once
per leaf.  This engine replaces all three loops:

1. **LeafPlan** — computed once per ``init``/``update`` trace from the param
   *structure* (paths + shapes + dtypes only, so it is identical under
   ``jax.eval_shape`` and inside ``jit``): every '/'-joined leaf path is
   assigned a :class:`LeafRule` by the optimizer's ``assign`` function.

2. **Buckets** — leaves with identical ``(rule.kind, rule.sig, shape,
   dtype)`` are grouped.  E.g. all 12 ``layers/*/mlp/w1`` matrices of a
   deep config become one ``(12, m, n)`` stack.  Bucket names are stable
   and path-keyed — ``"<kind>__<first-leaf-path>"`` — so checkpoints
   save/restore by name, not by flatten order.

3. **Execution** — one ``jax.lax.scan`` over the stacked leading axis per
   bucket (the scan body is traced *once* regardless of layer count), or a
   single vectorized call when the rule provides ``vector_update`` (the
   fused Pallas GWT-Adam kernel consumes the whole ``(L, m, n)`` stack in
   one launch).

State layout::

    {"step": i32[],
     ["codec_key": u32[],]                # quantizing codecs only
     "buckets": {"<kind>__<path>": <stacked per-leaf state pytree>, ...}}

The per-leaf state inside a bucket is exactly what the pre-engine
optimizers stored per leaf, so migration from the legacy
``{"step", "leaves": (...,)}`` tuple layout is a pure regrouping
(:meth:`Engine.migrate_legacy` / :meth:`Engine.to_legacy`).

**State substrate (DESIGN.md §8):** rules declare which state arrays are
*moment slots* (``LeafRule.slots``); :func:`build` takes a ``codec``
(``repro.optim.codec``) and stores slot arrays encoded — dequantize →
update → requantize fused into the per-bucket scan body (or handed whole
to a ``codec_native`` ``vector_update``, e.g. the fused GWT-Adam q8
kernel).  The default ``f32`` codec short-circuits every wrapper, so its
update graphs are bitwise-identical to the pre-codec engine.  Migration
between codecs on resume is :func:`transcode`.

Custom rules: pass any ``assign(path, leaf) -> LeafRule`` to :func:`build`
(see DESIGN.md and the README rule table).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.optim import codec as codec_lib
from repro.optim.base import Optimizer, flatten_with_paths


class LeafRule(NamedTuple):
    """How one leaf updates.

    * ``kind`` — rule family name (``plain`` / ``gwt_last`` / ``gwt_first``
      / ``lowrank`` / ``sgd`` / ``muon`` / custom); becomes the bucket-name
      prefix.
    * ``sig`` — extra static signature: leaves bucket together only when
      their ``(kind, sig, shape, dtype)`` all match.  Hyperparameters that
      vary *between leaves of one optimizer* must be in ``sig``.
    * ``init(leaf) -> state`` — per-leaf state pytree (arrays only) from an
      array or ``ShapeDtypeStruct``.
    * ``update(g, p, state, step, leaf_id) -> (new_p, new_state)`` — one
      leaf's update.  ``leaf_id`` is the i32 flatten-order index (used e.g.
      by APOLLO's per-leaf random projector).
    * ``vector_update`` — optional ``(g_stk, p_stk, state_stk, step,
      leaf_ids) -> (new_p_stk, new_state_stk)`` over the whole ``(L, ...)``
      stack in one call; used instead of the scan when present (fused
      kernels).
    * ``slots`` — bool pytree mirroring the per-leaf state structure:
      True marks a *moment slot* the state codec may re-encode (int8 etc.).
      ``None`` = no slots; the codec never touches this rule's state.
    * ``codec_native`` — the rule's ``vector_update`` handles encoded
      slots itself (signature grows a trailing ``codec_key``); the engine
      passes the encoded bucket straight through instead of wrapping with
      generic decode/encode (the fused GWT-Adam q8 kernel requantizes in
      its epilogue).
    * ``taps`` — optional observability hook ``(g_stk, p_stk, new_p_stk,
      old_state_stk, new_state_stk, step) -> {name: f32 scalar}`` adding
      rule-specific scalars (wavelet band energy, limiter clip count) to
      the bucket's generic taps.  States arrive in *stored* layout —
      encoded slots stay encoded — so taps piggyback on already-computed
      results (e.g. the fused kernel's ``prev_norm`` pass) instead of
      re-deriving them.  Only runs inside ``Optimizer.tapped_update``;
      the plain ``update`` graph never traces it (DESIGN.md §12).
    """

    kind: str
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[jax.Array, Any]]
    sig: Tuple = ()
    vector_update: Optional[Callable[..., Tuple[jax.Array, Any]]] = None
    slots: Any = None
    codec_native: bool = False
    taps: Optional[Callable[..., Any]] = None


class Bucket(NamedTuple):
    name: str
    rule: LeafRule
    indices: Tuple[int, ...]   # positions in flatten order
    paths: Tuple[str, ...]
    template: Any              # ShapeDtypeStruct of the (shared) leaf shape


class LeafPlan(NamedTuple):
    buckets: Tuple[Bucket, ...]
    paths: Tuple[str, ...]
    n_leaves: int


def build_plan(assign: Callable[[str, Any], LeafRule], params) -> LeafPlan:
    """Group leaves into buckets of identical ``(kind, sig, shape, dtype)``.

    Depends only on paths/shapes/dtypes — safe to recompute at trace time.
    """
    paths, leaves, _ = flatten_with_paths(params)
    groups: dict = {}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        rule = assign(path, leaf)
        key = (rule.kind, rule.sig, tuple(leaf.shape), str(jnp.dtype(leaf.dtype)))
        if key in groups:
            groups[key][1].append(i)
        else:
            groups[key] = (rule, [i])
    buckets = []
    for rule, idxs in sorted(groups.values(), key=lambda g: g[1][0]):
        first = paths[idxs[0]].replace("/", ".")
        lf = leaves[idxs[0]]
        buckets.append(Bucket(name=f"{rule.kind}__{first}", rule=rule,
                              indices=tuple(idxs),
                              paths=tuple(paths[i] for i in idxs),
                              template=jax.ShapeDtypeStruct(
                                  tuple(lf.shape), jnp.dtype(lf.dtype))))
    return LeafPlan(tuple(buckets), tuple(paths), len(paths))


def _stack_states(per_leaf: Sequence[Any]):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_leaf)


def _slice_state(state, j: int):
    return jax.tree_util.tree_map(lambda a: a[j], state)


class Engine:
    """Plan/migration companion of an engine-built :class:`Optimizer`."""

    def __init__(self, assign: Callable[[str, Any], LeafRule],
                 bucketed: bool = True, codec="f32", codec_seed: int = 0):
        self.assign = assign
        self.bucketed = bucketed
        self.codec = codec_lib.get_codec(codec)
        self.codec_seed = codec_seed
        self._validated: set = set()  # (kind, sig, shape, dtype) probed OK

    def plan(self, params) -> LeafPlan:
        plan = build_plan(self.assign, params)
        self._validate(plan)
        return plan

    def _validate(self, plan: LeafPlan) -> None:
        """Fail at build time — with the leaf path — when a rule cannot
        handle a leaf it was assigned (e.g. a wavelet rule forced onto a
        non-divisible recurrent kernel).  ``eval_shape`` probes ``init`` and
        one raw-state ``update`` per distinct ``(kind, sig, shape, dtype)``
        signature, so the error surfaces before any scan/jit trace and the
        steady-state cost is a memoized set lookup."""
        for b in plan.buckets:
            leaf = jax.ShapeDtypeStruct(b.template.shape, b.template.dtype)
            key = (b.rule.kind, b.rule.sig, leaf.shape, str(leaf.dtype))
            if key in self._validated:
                continue

            def probe(p):
                st = b.rule.init(p)
                g = jnp.zeros(p.shape, p.dtype)
                step = jnp.zeros((), jnp.int32)
                return b.rule.update(g, p, st, step, 0)

            try:
                jax.eval_shape(probe, leaf)
            except Exception as e:  # noqa: BLE001 — re-raise with the path
                raise ValueError(
                    f"rule {b.rule.kind!r} cannot handle leaf "
                    f"{b.paths[0]!r} (shape={tuple(leaf.shape)}, "
                    f"dtype={leaf.dtype}): {e}") from e
            self._validated.add(key)

    def codec_key(self) -> Optional[jax.Array]:
        """The concrete uint32 rounding key ``init`` stores in
        ``opt_state["codec_key"]`` (None for passthrough codecs)."""
        if self.codec.passthrough:
            return None
        return codec_lib.make_key(self.codec_seed)

    # -- legacy tuple-layout interop ---------------------------------------
    def legacy_like(self, params):
        """Abstract state in the pre-engine layout ``{"step", "leaves"}``
        (per-leaf states as a flatten-order tuple) — used as the ``like``
        tree when restoring an old checkpoint.  Legacy checkpoints predate
        the codec layer, so states here are raw (f32) regardless of this
        engine's codec; transcode after migrating.  ShapeDtypeStruct
        leaves: no allocation."""
        def build(p):
            paths, leaves, _ = flatten_with_paths(p)
            per_leaf = tuple(self.assign(pa, l).init(l)
                             for pa, l in zip(paths, leaves))
            return {"step": jnp.zeros((), jnp.int32), "leaves": per_leaf}
        return jax.eval_shape(build, params)

    def migrate_legacy(self, old_state, params):
        """Regroup a legacy ``{"step", "leaves": (...,)}`` state into the
        named bucket layout (values are untouched, only stacked)."""
        plan = self.plan(params)
        leaves = old_state["leaves"]
        buckets = {b.name: _stack_states([leaves[i] for i in b.indices])
                   for b in plan.buckets}
        return {"step": old_state["step"], "buckets": buckets}

    def to_legacy(self, state, params):
        """Inverse of :meth:`migrate_legacy` (downgrade path / tests)."""
        plan = self.plan(params)
        per_leaf = [None] * plan.n_leaves
        for b in plan.buckets:
            st = state["buckets"][b.name]
            for j, i in enumerate(b.indices):
                per_leaf[i] = _slice_state(st, j)
        return {"step": state["step"], "leaves": tuple(per_leaf)}


def _constrain_bucket(state, sharding_tree):
    """Pin one bucket's stacked state to its NamedSharding tree (a
    per-bucket hint from ``distributed.sharding.gwt_state_shardings``).
    Works eagerly, under ``jit``, and under ``eval_shape`` — NamedSharding
    leaves carry their own mesh, so no ambient context is needed.  A hint
    that doesn't fit the state — wrong structure (stale optimizer config,
    wrong dict level) or shape-incompatible specs — is a caller bug and
    raises rather than silently skipping placement."""
    if sharding_tree is None:
        return state
    if (jax.tree_util.tree_structure(state)
            != jax.tree_util.tree_structure(sharding_tree)):
        raise ValueError(
            f"state_shardings hint structure "
            f"{jax.tree_util.tree_structure(sharding_tree)} does not match "
            f"bucket state {jax.tree_util.tree_structure(state)} — pass "
            f"gwt_state_shardings(...)['buckets'] for the SAME "
            f"level/host/eligible configuration")
    return jax.tree_util.tree_map(jax.lax.with_sharding_constraint,
                                  state, sharding_tree)


def _decode_stacked(codec, mask, st):
    return jax.vmap(lambda s: codec_lib.tree_decode(codec, mask, s))(st)


def _encode_stacked(codec, mask, st, key, step, lids):
    return jax.vmap(
        lambda s, lid: codec_lib.tree_encode(codec, mask, s, key, step,
                                             lid))(st, lids)


def _codec_taps(ns) -> dict:
    """Generic int8-substrate taps from an *encoded* stacked bucket state:
    saturation rate (fraction of ``q`` codes at the ±127 rails — persistent
    saturation means the blocked absmax scale is pinned by outliers) and
    the max block absmax (``scale·127``).  Empty for unencoded buckets."""
    sat = None
    total = 0
    absmax = None
    for path, leaf in zip(*flatten_with_paths(ns)[:2]):
        tail = path.rsplit("/", 1)[-1]
        if tail == "q" and leaf.dtype == jnp.int8:
            hits = jnp.sum((jnp.abs(leaf.astype(jnp.int32)) >= 127)
                           .astype(jnp.float32))
            sat = hits if sat is None else sat + hits
            total += int(leaf.size)
        elif tail == "scale" and leaf.dtype == jnp.float32:
            mx = jnp.max(leaf)
            absmax = mx if absmax is None else jnp.maximum(absmax, mx)
    if total == 0:
        return {}
    out = {"q8_sat_rate": sat / jnp.float32(total)}
    if absmax is not None:
        out["q8_absmax"] = absmax * jnp.float32(127.0)
    return out


def build(assign: Callable[[str, Any], LeafRule],
          bucketed: bool = True, state_shardings=None,
          codec="f32", codec_seed: int = 0) -> Optimizer:
    """Build an :class:`Optimizer` from a leaf-rule assignment.

    ``bucketed=True`` (default) executes one scan / vectorized kernel call
    per bucket; ``bucketed=False`` unrolls leaf-by-leaf (the pre-engine
    reference semantics — same state layout, used in equivalence tests).

    ``state_shardings`` — optional per-bucket sharding hints: a dict
    ``{bucket_name: NamedSharding tree}`` (the ``"buckets"`` entry of
    ``distributed.sharding.gwt_state_shardings``).  ``init`` places each
    bucket's stacked state on its hinted layout and ``update`` re-pins the
    new state, so the sharded train path never round-trips optimizer
    state through an unconstrained (GSPMD's-choice) layout.

    ``codec`` — state-substrate codec (name or instance, see
    ``repro.optim.codec``).  Rule state arrays marked in ``rule.slots``
    are stored encoded; decode → update → requantize happens per leaf
    inside the scan body (never materializing a decoded bucket), or inside
    a ``codec_native`` rule's own fused ``vector_update``.  ``codec_seed``
    derives the stochastic-rounding key carried in the state.
    """
    eng = Engine(assign, bucketed, codec=codec, codec_seed=codec_seed)
    cdc = eng.codec
    quant = not cdc.passthrough
    hints = state_shardings or {}

    def init(params):
        plan = eng.plan(params)
        _, leaves, _ = flatten_with_paths(params)

        def leaf_init(rule, leaf):
            st = rule.init(leaf)
            return codec_lib.tree_init(cdc, rule.slots, st) if quant else st

        buckets = {
            b.name: _constrain_bucket(
                _stack_states([leaf_init(b.rule, leaves[i])
                               for i in b.indices]),
                hints.get(b.name))
            for b in plan.buckets}
        out = {"step": jnp.zeros((), jnp.int32), "buckets": buckets}
        if quant:
            out["codec_key"] = eng.codec_key()
        return out

    def _run(grads, state, params, with_taps: bool):
        # ``with_taps`` is a Python-level flag resolved at trace time: the
        # False trace is op-for-op the pre-taps update graph, so the plain
        # ``update`` channel stays bitwise-identical (DESIGN.md §12).
        step = state["step"]
        key = state.get("codec_key")
        plan = eng.plan(params)
        _, gleaves, treedef = flatten_with_paths(grads)
        pleaves = jax.tree_util.tree_leaves(params)
        new_leaves = [None] * plan.n_leaves
        new_buckets = {}
        taps: dict = {}
        for b in plan.buckets:
            st = state["buckets"][b.name]
            lids = jnp.asarray(b.indices, jnp.int32)
            coded = quant and b.rule.slots is not None

            def leaf_update(g, p, s, lid, rule=b.rule, coded=coded):
                # dequant -> update -> requant, fused per leaf: the decoded
                # f32 moments live only inside this body's trace.
                if coded:
                    s = codec_lib.tree_decode(cdc, rule.slots, s)
                new_p, ns = rule.update(g, p, s, step, lid)
                if coded:
                    ns = codec_lib.tree_encode(cdc, rule.slots, ns, key,
                                               step, lid)
                return new_p, ns

            if not bucketed:
                outs = [leaf_update(gleaves[i], pleaves[i],
                                    _slice_state(st, j), lids[j])
                        for j, i in enumerate(b.indices)]
                np_stk = jnp.stack([o[0] for o in outs])
                ns = _stack_states([o[1] for o in outs])
            else:
                g_stk = jnp.stack([gleaves[i] for i in b.indices])
                p_stk = jnp.stack([pleaves[i] for i in b.indices])
                if b.rule.vector_update is not None:
                    if coded and b.rule.codec_native:
                        np_stk, ns = b.rule.vector_update(
                            g_stk, p_stk, st, step, lids, key)
                    elif coded:
                        dec = _decode_stacked(cdc, b.rule.slots, st)
                        np_stk, ns = b.rule.vector_update(g_stk, p_stk, dec,
                                                          step, lids)
                        ns = _encode_stacked(cdc, b.rule.slots, ns, key,
                                             step, lids)
                    else:
                        np_stk, ns = b.rule.vector_update(g_stk, p_stk, st,
                                                          step, lids)
                else:
                    def body(_, xs):
                        g, p, s, lid = xs
                        return None, leaf_update(g, p, s, lid)
                    _, (np_stk, ns) = jax.lax.scan(
                        body, None, (g_stk, p_stk, st, lids))
                if with_taps:
                    g32 = g_stk.astype(jnp.float32)
                    d32 = (np_stk.astype(jnp.float32)
                           - p_stk.astype(jnp.float32))
                    tp = {"grad_ssq": jnp.sum(g32 * g32),
                          "update_ssq": jnp.sum(d32 * d32)}
                    if coded:
                        tp.update(_codec_taps(ns))
                    if b.rule.taps is not None:
                        tp.update(b.rule.taps(g_stk, p_stk, np_stk, st, ns,
                                              step))
                    for k, v in tp.items():
                        taps[f"{b.name}/{k}"] = jnp.asarray(v, jnp.float32)
            new_buckets[b.name] = _constrain_bucket(ns, hints.get(b.name))
            for j, i in enumerate(b.indices):
                new_leaves[i] = np_stk[j]
        out = {"step": step + 1, "buckets": new_buckets}
        if quant:
            out["codec_key"] = key
        return jax.tree_util.tree_unflatten(treedef, new_leaves), out, taps

    def update(grads, state, params):
        new_params, out, _ = _run(grads, state, params, with_taps=False)
        return new_params, out

    def tapped_update(grads, state, params):
        """``update`` plus per-bucket observability scalars — the on-device
        tap channel (DESIGN.md §12).  Taps need the stacked grads/params
        only the bucketed path materializes, so the unrolled reference
        engine exposes no tapped channel."""
        return _run(grads, state, params, with_taps=True)

    return Optimizer(init, update, engine=eng,
                     tapped_update=tapped_update if bucketed else None)


def transcode(state, params, src: Optimizer, dst: Optimizer):
    """Re-encode an optimizer state between codecs (``--resume`` across a
    ``--state-codec`` change): decode every slot with ``src``'s codec,
    re-encode with ``dst``'s.  Both optimizers must share the same rule
    assignment (same model/optimizer config) — only the substrate differs.
    Values are preserved up to the destination codec's quantization."""
    eng_s, eng_d = src.engine, dst.engine
    plan = eng_s.plan(params)
    step = state["step"]
    key = eng_d.codec_key()
    new_buckets = {}
    for b in plan.buckets:
        st = state["buckets"][b.name]
        if b.rule.slots is not None and not eng_s.codec.passthrough:
            st = _decode_stacked(eng_s.codec, b.rule.slots, st)
        if b.rule.slots is not None and not eng_d.codec.passthrough:
            lids = jnp.asarray(b.indices, jnp.int32)
            st = _encode_stacked(eng_d.codec, b.rule.slots, st, key, step,
                                 lids)
        new_buckets[b.name] = st
    out = {"step": step, "buckets": new_buckets}
    if key is not None:
        out["codec_key"] = key
    return out


def state_bytes(optimizer: Optimizer, params) -> int:
    """Exact optimizer-state bytes via ``eval_shape`` — no analytic model,
    correct for every host/rule combination (train.py's accounting).
    Codec-aware for free: ``init`` builds the encoded layout (int8 ``q`` +
    f32 scales), so the abstract tree already has the substrate's dtypes."""
    abstract = jax.eval_shape(optimizer.init, params)
    return sum(l.size * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(abstract))


def jit_update(optimizer: Optimizer, donate: bool = True):
    """Jit the bucketed ``update`` with ``(grads, state)`` donated.

    The bucketed stacks then update in place — one live copy of the
    optimizer state instead of old+new double-buffering, and the gradient
    buffers are recycled into the outputs.  ``params`` (arg 2) is never
    donated here: standalone-update callers usually still own it.  Inside
    a donated *train step* the whole ``(params, opt_state)`` pair aliases
    through (see ``lm.make_train_step(donate=True)``)."""
    return jax.jit(optimizer.update,
                   donate_argnums=(0, 1) if donate else ())


def live_update_bytes(compiled) -> Optional[int]:
    """Peak live bytes of a compiled update/train-step executable:
    ``arguments + outputs − donation aliases + temporaries``, straight
    from XLA's buffer assignment.  ``None`` when the backend exposes no
    ``memory_analysis`` (the benchmark then skips the donation check)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    return int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
