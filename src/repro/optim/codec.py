"""Pluggable optimizer-state substrate: the ``StateCodec`` layer.

Every :class:`~repro.optim.engine.LeafRule` declares which arrays of its
per-leaf state are *moment slots* (``LeafRule.slots`` — a bool pytree
mirroring the state structure).  The engine stores slot arrays through a
codec:

* ``f32`` — passthrough (default).  The engine skips the codec entirely,
  so updates are bitwise-identical to the pre-codec engine.
* ``int8`` — blocked 8-bit: each slot array is flattened (row-major) and
  quantized in blocks of ``block`` elements against a per-block absmax
  scale (``scale = absmax/127``), with **stochastic rounding** so repeated
  requantization stays unbiased (FOAM / bitsandbytes-style).  The encoded
  slot is ``{"q": int8 (original shape), "scale": f32 (nb,)}`` with
  ``nb = ceil(size/block)`` — ~``1/4 + 1/(4·block)`` of the f32 bytes.

Rounding randomness is **counter-based**, not ``jax.random``: a
murmur-style uint32 mixing hash of ``(codec_key, step, slot_idx, leaf_id,
element_idx)``.  Consequences the rest of the stack relies on:

* identical bits under ``lax.scan``, unrolled, vmapped, and Pallas
  execution (plain uint32 arithmetic, no backend RNG state);
* preempt/resume is bitwise: ``codec_key`` lives in ``opt_state`` (saved
  in every checkpoint) and ``step`` is the optimizer step, so a resumed
  run requantizes with exactly the interrupted run's bits;
* traceable under ``jax.eval_shape`` (state accounting needs no key).

The hash/round helpers are module-level so the fused Pallas kernel
(``repro.kernels.gwt_adam.kernel``) can reuse them inside its requant
epilogue — one definition of the bits, every backend agrees.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 64

_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLD = 0x9E3779B9


def _fmix(h: jax.Array) -> jax.Array:
    """murmur3 finalizer: bijective uint32 avalanche mix."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_M1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_M2)
    h = h ^ (h >> 16)
    return h


def _fold(h: jax.Array, x) -> jax.Array:
    return _fmix(h ^ (jnp.asarray(x).astype(jnp.uint32) * jnp.uint32(_GOLD)))


def make_key(seed: int) -> jax.Array:
    """Concrete uint32 codec key from an integer seed (stored in
    ``opt_state["codec_key"]``; constant over a run)."""
    return _fold(jnp.uint32(0x8BADF00D), jnp.uint32(seed & 0xFFFFFFFF))


def slot_salt(key, step, slot: int, leaf_id) -> jax.Array:
    """Per-(key, step, slot, leaf) salt; elementwise over ``leaf_id`` so a
    vector of leaf ids yields a vector of salts."""
    return _fold(_fold(_fold(jnp.asarray(key, jnp.uint32), step),
                       jnp.uint32(slot)), leaf_id)


def uniform01(salt, idx: jax.Array) -> jax.Array:
    """Deterministic uniforms in [0, 1): hash of (salt, element index),
    24 mantissa-exact bits."""
    bits = _fmix(jnp.asarray(salt, jnp.uint32)
                 ^ (idx.astype(jnp.uint32) * jnp.uint32(_GOLD)))
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


# ---------------------------------------------------------------------------
# Blocked int8 quantization with stochastic rounding
# ---------------------------------------------------------------------------

def num_blocks(size: int, block: int = DEFAULT_BLOCK) -> int:
    return max(1, -(-size // block))


def blocked_quant(x: jax.Array, salt, block: int = DEFAULT_BLOCK,
                  rounding: str = "stochastic"):
    """``x -> (q int8 (x.shape), scale f32 (nb,))``; row-major flat blocks.

    ``scale = absmax/127`` per block; elements are divided by their block's
    scale and stochastically rounded (``floor(y) + (u < frac(y))`` with
    ``u = uniform01(salt, flat_idx)``) — unbiased, error ≤ one quantum
    (= scale).  All-zero blocks encode as ``scale = 0`` exactly.

    ``rounding="nearest"`` rounds to the nearest level instead (``salt``
    is ignored): half the worst-case error, but biased under repeated
    requantization — right for write-once payloads (the serving KV cache,
    which encodes each entry exactly once), wrong for optimizer moments.
    """
    shape = tuple(x.shape)
    n = int(x.size)
    nb = num_blocks(n, block)
    xf = x.astype(jnp.float32).reshape(-1)
    if nb * block != n:
        xf = jnp.pad(xf, (0, nb * block - n))
    blocks = xf.reshape(nb, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scale = absmax * jnp.float32(1.0 / 127.0)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0).astype(jnp.float32)
    y = blocks * inv[:, None]
    if rounding == "nearest":
        q = jnp.round(y)
    elif rounding == "stochastic":
        idx = jax.lax.iota(jnp.uint32, nb * block).reshape(nb, block)
        lo = jnp.floor(y)
        q = lo + (uniform01(salt, idx) < (y - lo)).astype(jnp.float32)
    else:
        raise ValueError(f"rounding {rounding!r}: expected 'stochastic' "
                         "or 'nearest'")
    q = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)
    return q.reshape(-1)[:n].reshape(shape), scale


def blocked_dequant(q: jax.Array, scale: jax.Array,
                    block: int = DEFAULT_BLOCK) -> jax.Array:
    shape = tuple(q.shape)
    n = int(q.size)
    nb = int(scale.shape[-1])
    qf = q.astype(jnp.float32).reshape(-1)
    if nb * block != n:
        qf = jnp.pad(qf, (0, nb * block - n))
    out = (qf.reshape(nb, block) * scale.astype(jnp.float32)[:, None])
    return out.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------

class F32Codec:
    """Passthrough: slots are stored exactly as the rule produced them.
    The engine special-cases ``passthrough`` and never even calls these."""

    name = "f32"
    passthrough = True

    def init(self, x):
        return x

    def encode(self, x, salt):
        return x

    def decode(self, enc):
        return enc


class BlockedInt8Codec:
    """Blocked absmax int8 with stochastic rounding (see module doc)."""

    name = "int8"
    passthrough = False

    def __init__(self, block: int = DEFAULT_BLOCK):
        self.block = block

    def init(self, x):
        # zeros encode exactly (scale 0) — built structurally, no hashing,
        # so rule init stays traceable under eval_shape without a key.
        nb = num_blocks(int(x.size), self.block)
        return {"q": jnp.zeros(tuple(x.shape), jnp.int8),
                "scale": jnp.zeros((nb,), jnp.float32)}

    def encode(self, x, salt):
        q, scale = blocked_quant(x, salt, self.block)
        return {"q": q, "scale": scale}

    def decode(self, enc):
        return blocked_dequant(enc["q"], enc["scale"], self.block)


CODECS = {"f32": F32Codec, "int8": BlockedInt8Codec,
          "blocked_int8": BlockedInt8Codec}


def get_codec(codec) -> Any:
    """Name or instance -> codec instance."""
    if isinstance(codec, str):
        if codec not in CODECS:
            raise ValueError(
                f"unknown state codec {codec!r}; choices: {sorted(CODECS)}")
        return CODECS[codec]()
    return codec


# ---------------------------------------------------------------------------
# Slot-tree traversal: apply the codec to the True leaves of a rule's
# ``slots`` mask.  Rule states here are dicts/bare arrays only; slot
# indices are assigned in sorted-key order (matching jax's dict-key
# ordering) so the generic scan path and hand-fused kernels agree on
# which salt quantizes which moment.
# ---------------------------------------------------------------------------

def map_slots(mask, state, fn):
    """``fn(slot_idx, slot_value)`` on each True mask leaf; other values
    pass through.  ``mask`` must mirror ``state``'s dict structure."""
    counter = [0]

    def rec(m, s):
        if m is True:
            i = counter[0]
            counter[0] += 1
            return fn(i, s)
        if m is None or m is False:
            return s
        if not isinstance(m, dict):
            raise TypeError(f"slots mask node {type(m).__name__}: expected "
                            "bool or dict")
        return {k: rec(m[k], s[k]) for k in sorted(s.keys())}

    return rec(mask, state)


def tree_init(codec, mask, state):
    if codec.passthrough or mask is None:
        return state
    return map_slots(mask, state, lambda i, s: codec.init(s))


def tree_decode(codec, mask, state):
    if codec.passthrough or mask is None:
        return state
    return map_slots(mask, state, lambda i, s: codec.decode(s))


def tree_encode(codec, mask, state, key, step, leaf_id):
    if codec.passthrough or mask is None:
        return state
    return map_slots(
        mask, state,
        lambda i, s: codec.encode(s, slot_salt(key, step, i, leaf_id)))
