"""Full-tree standard optimizers: Adam, Adam-mini, MUON, SGD-momentum.

These are the paper's full-rank baselines (Table II "Full-Rank Adam",
"MUON"; Fig. 4 hosts).  Same ``Optimizer`` interface as GWT/GaLore/APOLLO so
examples/benchmarks can swap them by name.

All are thin rule declarations over the shared bucketed engine
(``repro.optim.engine``): same-shaped leaves are stacked and updated by one
``lax.scan`` body instead of one unrolled update graph per leaf.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.optim import engine, hosts as hosts_lib
from repro.optim.base import Optimizer
from repro.optim.schedules import Schedule, constant


def _norm_lr(lr):
    return constant(lr) if isinstance(lr, (int, float)) else lr


def host_rule(kind: str, host: hosts_lib.Host, lr: Schedule,
              weight_decay: float = 0.0) -> engine.LeafRule:
    """Plain host update on the full tensor: ``p -= lr·lr_mult·precond``."""

    def update(g, p, state, step, leaf_id):
        lr_t = lr(step)
        precond, _, lr_mult, state = host.update(g, state, step)
        q = p.astype(jnp.float32) - (lr_t * lr_mult) * precond.astype(jnp.float32)
        if weight_decay:
            q = q - lr_t * weight_decay * p.astype(jnp.float32)
        return q.astype(p.dtype), state

    return engine.LeafRule(kind=kind, init=host.init, update=update,
                           slots=host.slots)


def from_host(lr: Schedule | float, host: hosts_lib.Host,
              weight_decay: float = 0.0, bucketed: bool = True,
              state_codec="f32") -> Optimizer:
    rule = host_rule(host.name, host, _norm_lr(lr), weight_decay)
    return engine.build(lambda path, leaf: rule, bucketed=bucketed,
                        codec=state_codec)


def adam(lr, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.0,
         state_dtype=jnp.float32, bucketed: bool = True,
         state_codec="f32") -> Optimizer:
    return from_host(lr, hosts_lib.adam(b1, b2, eps, state_dtype),
                     weight_decay, bucketed, state_codec)


def adam_mini(lr, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.0,
              state_dtype=jnp.float32, bucketed: bool = True,
              state_codec="f32") -> Optimizer:
    return from_host(lr, hosts_lib.adam_mini(b1, b2, eps, state_dtype),
                     weight_decay, bucketed, state_codec)


def sgd(lr, momentum: float = 0.9, state_dtype=jnp.float32,
        bucketed: bool = True, state_codec="f32") -> Optimizer:
    lr = _norm_lr(lr)

    def update(g, p, m, step, leaf_id):
        lr_t = lr(step)
        m = momentum * m.astype(jnp.float32) + g.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr_t * m).astype(p.dtype)
        return new_p, m.astype(state_dtype)

    rule = engine.LeafRule(
        kind="sgd", init=lambda p: jnp.zeros(p.shape, state_dtype),
        update=update, slots=True)
    return engine.build(lambda path, leaf: rule, bucketed=bucketed,
                        codec=state_codec)


def muon(lr, beta=0.95, ns_steps=5, adam_lr: Optional[float] = None,
         state_dtype=jnp.float32, bucketed: bool = True,
         state_codec="f32") -> Optimizer:
    """MUON on ≥2-D matmul weights, Adam on the rest — embeddings/heads/
    norms excluded per standard MUON practice (orthogonalizing the
    embedding matrix diverges)."""
    from repro.optim.base import default_eligible
    lr = _norm_lr(lr)
    adam_sched = _norm_lr(adam_lr) if adam_lr is not None else lr
    muon_r = host_rule("muon", hosts_lib.muon(beta, ns_steps,
                                              state_dtype=state_dtype), lr)
    adam_r = host_rule("plain", hosts_lib.adam(state_dtype=state_dtype),
                       adam_sched)

    def is_muon(path, p):
        return (p.ndim >= 2 and min(p.shape[-2:]) > 1
                and default_eligible(path, p))

    return engine.build(
        lambda path, leaf: muon_r if is_muon(path, leaf) else adam_r,
        bucketed=bucketed, codec=state_codec)
