"""Full-tree standard optimizers: Adam, Adam-mini, MUON, SGD-momentum.

These are the paper's full-rank baselines (Table II "Full-Rank Adam",
"MUON"; Fig. 4 hosts).  Same ``Optimizer`` interface as GWT/GaLore/APOLLO so
examples/benchmarks can swap them by name.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim import hosts as hosts_lib
from repro.optim.base import Optimizer, flatten_with_paths
from repro.optim.schedules import Schedule, constant


def _norm_lr(lr):
    return constant(lr) if isinstance(lr, (int, float)) else lr


def from_host(lr: Schedule | float, host: hosts_lib.Host,
              weight_decay: float = 0.0) -> Optimizer:
    lr = _norm_lr(lr)

    def init(params):
        _, leaves, _ = flatten_with_paths(params)
        return {"step": jnp.zeros((), jnp.int32),
                "leaves": tuple(host.init(p) for p in leaves)}

    def update(grads, state, params):
        step = state["step"]
        lr_t = lr(step)
        _, gleaves, treedef = flatten_with_paths(grads)
        pleaves = jax.tree_util.tree_leaves(params)
        new_p, new_s = [], []
        for g, ls, p in zip(gleaves, state["leaves"], pleaves):
            precond, _, lr_mult, ls = host.update(g, ls, step)
            q = p.astype(jnp.float32) - (lr_t * lr_mult) * precond.astype(jnp.float32)
            if weight_decay:
                q = q - lr_t * weight_decay * p.astype(jnp.float32)
            new_p.append(q.astype(p.dtype))
            new_s.append(ls)
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                {"step": step + 1, "leaves": tuple(new_s)})

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.0,
         state_dtype=jnp.float32) -> Optimizer:
    return from_host(lr, hosts_lib.adam(b1, b2, eps, state_dtype), weight_decay)


def adam_mini(lr, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.0,
              state_dtype=jnp.float32) -> Optimizer:
    return from_host(lr, hosts_lib.adam_mini(b1, b2, eps, state_dtype), weight_decay)


def sgd(lr, momentum: float = 0.9, state_dtype=jnp.float32) -> Optimizer:
    lr = _norm_lr(lr)

    def init(params):
        _, leaves, _ = flatten_with_paths(params)
        return {"step": jnp.zeros((), jnp.int32),
                "leaves": tuple(jnp.zeros(p.shape, state_dtype) for p in leaves)}

    def update(grads, state, params):
        step = state["step"]
        lr_t = lr(step)
        _, gleaves, treedef = flatten_with_paths(grads)
        pleaves = jax.tree_util.tree_leaves(params)
        new_p, new_s = [], []
        for g, m, p in zip(gleaves, state["leaves"], pleaves):
            m = momentum * m.astype(jnp.float32) + g.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr_t * m).astype(p.dtype))
            new_s.append(m.astype(m.dtype))
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                {"step": step + 1, "leaves": tuple(new_s)})

    return Optimizer(init, update)


def muon(lr, beta=0.95, ns_steps=5, adam_lr: Optional[float] = None,
         state_dtype=jnp.float32) -> Optimizer:
    """MUON on ≥2-D matmul weights, Adam on the rest — embeddings/heads/
    norms excluded per standard MUON practice (orthogonalizing the
    embedding matrix diverges)."""
    from repro.optim.base import default_eligible
    lr = _norm_lr(lr)
    mh = hosts_lib.muon(beta, ns_steps, state_dtype=state_dtype)
    ah = hosts_lib.adam(state_dtype=state_dtype)
    adam_sched = _norm_lr(adam_lr) if adam_lr is not None else lr

    def is_muon(path, p):
        return (p.ndim >= 2 and min(p.shape[-2:]) > 1
                and default_eligible(path, p))

    def init(params):
        paths, leaves, _ = flatten_with_paths(params)
        return {"step": jnp.zeros((), jnp.int32),
                "leaves": tuple((mh if is_muon(pa, p) else ah).init(p)
                                for pa, p in zip(paths, leaves))}

    def update(grads, state, params):
        step = state["step"]
        paths, gleaves, treedef = flatten_with_paths(grads)
        pleaves = jax.tree_util.tree_leaves(params)
        new_p, new_s = [], []
        for pa, g, ls, p in zip(paths, gleaves, state["leaves"], pleaves):
            host = mh if is_muon(pa, p) else ah
            lr_t = lr(step) if is_muon(pa, p) else adam_sched(step)
            precond, _, lr_mult, ls = host.update(g, ls, step)
            new_p.append((p.astype(jnp.float32)
                          - (lr_t * lr_mult) * precond.astype(jnp.float32)).astype(p.dtype))
            new_s.append(ls)
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                {"step": step + 1, "leaves": tuple(new_s)})

    return Optimizer(init, update)
