"""Model substrate: parameter builder (single source of truth for init /
logical axes / abstract shapes), norms, MLPs, embeddings.

Every parameter is created through ``Builder.param`` so the same model code
yields (a) initialized arrays, (b) the logical-axes tree the sharding rules
consume, (c) ShapeDtypeStruct trees for the dry-run — no mirror drift.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat


class Axes:
    """Logical-axis annotation; unregistered class ⇒ a pytree *leaf*."""

    __slots__ = ("names",)

    def __init__(self, names: Tuple[Optional[str], ...]):
        self.names = tuple(names)

    def __repr__(self):
        return f"Axes{self.names}"

    def __eq__(self, other):
        return isinstance(other, Axes) and self.names == other.names

    def __hash__(self):
        return hash(self.names)


class Builder:
    """mode: 'init' -> arrays; 'axes' -> Axes leaves; 'abstract' -> SDS."""

    def __init__(self, mode: str, key=None, dtype=jnp.bfloat16):
        assert mode in ("init", "axes", "abstract")
        self.mode = mode
        self.key = key
        self.dtype = dtype
        self._n = 0

    def _next_key(self):
        self._n += 1
        return jax.random.fold_in(self.key, self._n)

    def param(self, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              init: str = "normal", scale: Optional[float] = None,
              dtype=None):
        assert len(shape) == len(axes), (shape, axes)
        dtype = dtype or self.dtype
        if self.mode == "axes":
            return Axes(axes)
        if self.mode == "abstract":
            return jax.ShapeDtypeStruct(shape, dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if scale is None:  # fan-in scaled normal
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(self._next_key(), shape, jnp.float32)
                * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def wsc(x, *spec, ctx=None):
    """with_sharding_constraint that no-ops outside a mesh context.

    ``ctx`` (a MeshContext or mesh) pins the mesh explicitly; without it
    the compat-shimmed ambient mesh is used (CPU unit-test fallback)."""
    return compat.with_sharding_constraint(x, *spec,
                                           mesh=compat.unwrap_mesh(ctx))


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (LLaMA-family default)
# ---------------------------------------------------------------------------

def mlp_init(b: Builder, d_model: int, d_ff: int):
    return {
        "w_gate": b.param((d_model, d_ff), ("embed", "mlp")),
        "w_up": b.param((d_model, d_ff), ("embed", "mlp")),
        "w_down": b.param((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------

def embed_init(b: Builder, vocab: int, d_model: int, tie: bool):
    p = {"embedding": b.param((vocab, d_model), ("vocab", "embed"), scale=1.0)}
    if not tie:
        p["lm_head"] = b.param((d_model, vocab), ("embed", "vocab"))
    return p


def embed_apply(p, tokens: jax.Array, d_model: int) -> jax.Array:
    # multiply-by-sqrt(d) convention (gemma/llama variants differ; harmless)
    return p["embedding"][tokens] * jnp.asarray(
        np.sqrt(d_model), p["embedding"].dtype)


def logits_apply(p, x: jax.Array) -> jax.Array:
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    return x @ w


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Stable mean CE; logits f32; vocab axis may be model-sharded (GSPMD
    inserts the reductions)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


# ---------------------------------------------------------------------------
# LoRA adapter pairs (repro.models.lora builds trees out of these)
# ---------------------------------------------------------------------------

def lora_pair_init(key: jax.Array, shape, rank: int, dtype=jnp.float32):
    """Adapter pair for a (…, m, n) weight: ``a`` (…, m, r) fan-in normal,
    ``b`` (…, r, n) zeros — so the delta ``a @ b`` is exactly zero at init.
    Leading batch dims (stacked layers / experts) carry through."""
    m, n = shape[-2], shape[-1]
    a = jax.random.normal(key, tuple(shape[:-2]) + (m, rank), dtype)
    a = a / jnp.asarray(np.sqrt(m), dtype)
    b = jnp.zeros(tuple(shape[:-2]) + (rank, n), dtype)
    return {"a": a, "b": b}


def lora_delta(pair, alpha: float, rank: int) -> jax.Array:
    """(…, m, n) update: (a @ b) · α/r — batched matmul on leading dims."""
    return (pair["a"] @ pair["b"]) * jnp.asarray(alpha / rank,
                                                 pair["a"].dtype)
