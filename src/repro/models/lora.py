"""LoRA fine-tune path: frozen base + adapter leaves, substrate-agnostic.

The paper claims GWT compacts optimizer states for *fine-tuning* as well as
pre-training; this module opens that workload without touching any model's
forward code.  The parameter tree becomes::

    {"base": <original params>,            # bitwise-frozen
     "lora": <mirror subtree of {"a", "b"} pairs for target projections>}

and the forward pass runs on ``merge(tree)`` — base plus ``a @ b · α/r``
deltas — so every substrate (llama/moe/ssm/xlstm/encdec) works unchanged:
``merge`` only needs dict-shaped params, which all builders produce.

The frozen base is expressed through the engine's existing leaf-plan
routing: ``wrap_optimizer`` reassigns every ``base/…`` leaf to a zero-state
``FROZEN`` rule and leaves ``lora/…`` leaves on the inner optimizer's own
assignment — so ``engine.state_bytes`` counts adapter state only, and
"gwt2-LoRA" means the adapters' Adam moments live in wavelet subspaces.
"""

from __future__ import annotations

from types import SimpleNamespace
from typing import Tuple
import zlib

import jax
import jax.numpy as jnp

from repro.models.layers import lora_pair_init, lora_delta
from repro.optim import engine

# Last path segments that receive adapters: the attention and MLP
# projections (the paper's module scope).  Stacked-layer (n_periods, m, n)
# and per-expert (E, m, n) leaves batch through lora_pair_init unchanged.
LORA_TARGETS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def _is_target(name: str, leaf) -> bool:
    return name in LORA_TARGETS and getattr(leaf, "ndim", 0) >= 2


def inject(params, rank: int, key: jax.Array,
           targets: Tuple[str, ...] = LORA_TARGETS):
    """Wrap ``params`` into a ``{"base", "lora"}`` tree.

    ``merge(inject(p, r, k)) == p`` bitwise at init (``b`` starts at zero).
    Adapter keys derive from the leaf path (crc32-fold), so the same seed
    gives the same adapters regardless of dict iteration order.
    """

    def mirror(tree, prefix):
        out = {}
        for k, v in tree.items():
            path = f"{prefix}/{k}" if prefix else str(k)
            if isinstance(v, dict):
                sub = mirror(v, path)
                if sub:
                    out[k] = sub
            elif _is_target(str(k), v):
                kk = jax.random.fold_in(key, zlib.crc32(path.encode()))
                out[k] = lora_pair_init(kk, v.shape, rank, jnp.float32)
        return out

    return {"base": params, "lora": mirror(params, "")}


def merge(tree, alpha: float, rank: int):
    """Plain params: base + adapter deltas (cast back to base dtype)."""

    def walk(base, lora):
        out = {}
        for k, v in base.items():
            sub = lora.get(k) if isinstance(lora, dict) else None
            if isinstance(v, dict):
                out[k] = walk(v, sub or {})
            elif sub is not None:
                d = lora_delta(sub, alpha, rank)
                out[k] = (v.astype(jnp.float32) + d.astype(jnp.float32)
                          ).astype(v.dtype)
            else:
                out[k] = v
        return out

    return walk(tree["base"], tree["lora"])


def split_base(tree):
    """The frozen base subtree (for bitwise-frozen assertions)."""
    return tree["base"]


# Zero-state rule for frozen leaves: empty state dict -> zero bytes in
# ``state_bytes``, nothing to decode/encode, and the scan body returns the
# parameter unchanged (bitwise).
FROZEN = engine.LeafRule(kind="frozen",
                         init=lambda p: {},
                         update=lambda g, p, s, step, lid: (p, s))


def wrap_optimizer(inner) -> "engine.Optimizer":
    """Route ``base/…`` leaves to ``FROZEN``; everything else (the adapter
    ``a``/``b`` leaves) keeps the inner optimizer's own rule assignment —
    including its codec, so ``--state-codec int8`` quantizes adapter
    moments exactly as it would full-model moments."""
    eng = inner.engine
    if eng is None:
        raise ValueError("LoRA wrapping needs an engine-built optimizer")

    def assign(path, leaf):
        if path == "base" or path.startswith("base/"):
            return FROZEN
        return eng.assign(path, leaf)

    return engine.build(assign, bucketed=eng.bucketed,
                        codec=eng.codec, codec_seed=eng.codec_seed)


def loss_module(mod, alpha: float, rank: int):
    """A ``loss_fn``-shaped shim over ``mod`` that merges before the
    forward — drop-in for ``make_lm_evaluator`` and ``make_train_step``'s
    ``loss=`` hook."""

    def loss_fn(cfg, tree, batch, ctx=None):
        return mod.loss_fn(cfg, merge(tree, alpha, rank), batch, ctx=ctx)

    return SimpleNamespace(loss_fn=loss_fn)


def make_train_step(mod, cfg, optimizer, *, rank: int, alpha: float,
                    accum_steps: int = 1, ctx=None, donate: bool = False):
    """``mod.make_train_step`` with the merged-forward loss.  Gradients
    flow to base leaves too (merge is differentiable); the FROZEN rule
    discards them, keeping the base bitwise-stable."""
    shim = loss_module(mod, alpha, rank)
    return mod.make_train_step(cfg, optimizer, accum_steps=accum_steps,
                               ctx=ctx, donate=donate,
                               loss=shim.loss_fn)
