"""Encoder-decoder backbone (Seamless-M4T-v2 assignment config).

Audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings ``(B, S_frames, d_model)``; the speech encoder
here is the transformer stack those frames feed.  Decoder = causal
self-attention + cross-attention + SwiGLU MLP, teacher-forced training,
cached decode (self KV cache + cross KV precomputed at prefill).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, rope as rope_lib
from repro.models.layers import (Axes, Builder, cross_entropy, embed_apply,
                                 embed_init, logits_apply, mlp_apply,
                                 mlp_init, rms_norm)
from repro.models.lm import _cache_maker, _stack, constrain_batch
from repro.runtime.context import MeshContext


def _xattn_init(b: Builder, cfg) -> dict:
    d, hd, H, KV = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": b.param((d, H * hd), ("embed", "heads")),
        "wk": b.param((d, KV * hd), ("embed", "kv_heads")),
        "wv": b.param((d, KV * hd), ("embed", "kv_heads")),
        "wo": b.param((H * hd, d), ("heads", "embed")),
    }


def _xattn_apply(p, cfg, x, kv_src=None, kv_cache=None):
    """Cross-attention: q from x; k,v from kv_src (or precomputed cache)."""
    B, S, _ = x.shape
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if kv_cache is not None:
        k, v = kv_cache["k"], kv_cache["v"]
    else:
        T = kv_src.shape[1]
        k = (kv_src @ p["wk"]).reshape(B, T, KV, hd)
        v = (kv_src @ p["wv"]).reshape(B, T, KV, hd)
    kr = attention._repeat_kv(k, H)
    vr = attention._repeat_kv(v, H)
    if S * k.shape[1] > 4096 * 4096:   # long cross-attn: chunked online-softmax
        o = attention._flash_attn_noncausal(q, kr, vr)
    else:
        o = attention._direct_attn(q, kr, vr, causal_offset=int(1e9),
                                   window=0, cap=0.0)
    return o.reshape(B, S, H * hd) @ p["wo"], {"k": k, "v": v}


def _enc_block_init(b: Builder, cfg) -> dict:
    d = cfg.d_model
    return {"norm1": b.param((d,), (None,), init="zeros"),
            "attn": attention.attn_init(b, cfg),
            "norm2": b.param((d,), (None,), init="zeros"),
            "mlp": mlp_init(b, d, cfg.d_ff)}


def _dec_block_init(b: Builder, cfg) -> dict:
    d = cfg.d_model
    return {"norm1": b.param((d,), (None,), init="zeros"),
            "self_attn": attention.attn_init(b, cfg),
            "norm_x": b.param((d,), (None,), init="zeros"),
            "cross_attn": _xattn_init(b, cfg),
            "norm2": b.param((d,), (None,), init="zeros"),
            "mlp": mlp_init(b, d, cfg.d_ff)}


def _build(cfg, mode: str, key=None):
    b = Builder(mode, key, jnp.dtype(cfg.dtype))
    p: Dict[str, Any] = {
        "embed": embed_init(b, cfg.vocab, cfg.d_model, cfg.tie_embeddings),
        "encoder": _stack(b, cfg.n_enc_layers, lambda bb: _enc_block_init(bb, cfg)),
        "enc_norm": b.param((cfg.d_model,), (None,), init="zeros"),
        "decoder": _stack(b, cfg.n_dec_layers, lambda bb: _dec_block_init(bb, cfg)),
        "final_norm": b.param((cfg.d_model,), (None,), init="zeros"),
    }
    return p


def init(cfg, key):
    return _build(cfg, "init", key)


def param_axes(cfg):
    return _build(cfg, "axes")


def abstract_params(cfg):
    return _build(cfg, "abstract")


def encode(cfg, params, enc_embeds: jax.Array,
           ctx: MeshContext = None) -> jax.Array:
    if ctx is None:
        ctx = MeshContext.ambient()
    B, S, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cos, sin = rope_lib.rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        h = rms_norm(x, bp["norm1"], cfg.norm_eps)
        h, _ = attention.attn_apply(bp["attn"], cfg, h, cos, sin,
                                    mode="train", bidirectional=True)
        x = x + h
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        return constrain_batch(x + mlp_apply(bp["mlp"], h), ctx=ctx), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x0 = constrain_batch(enc_embeds.astype(jnp.dtype(cfg.dtype)), ctx=ctx)
    x, _ = jax.lax.scan(body_fn, x0, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_stack(cfg, params, tokens, enc_out, *, mode="train", caches=None,
                 ctx: MeshContext = None):
    if ctx is None:
        ctx = MeshContext.ambient()
    B, S = tokens.shape
    x = constrain_batch(embed_apply(params["embed"], tokens, cfg.d_model),
                        ctx=ctx)
    pos = caches["pos"] if caches is not None else None
    if mode == "decode":
        positions = jnp.broadcast_to(pos, (B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cos, sin = rope_lib.rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    def body(x, xs):
        bp, bc = xs
        h = rms_norm(x, bp["norm1"], cfg.norm_eps)
        h, new_self = attention.attn_apply(
            bp["self_attn"], cfg, h, cos, sin, mode=mode,
            cache=bc["self"] if bc is not None else None, pos=pos)
        x = x + h
        h = rms_norm(x, bp["norm_x"], cfg.norm_eps)
        h, new_cross = _xattn_apply(
            bp["cross_attn"], cfg, h, kv_src=enc_out,
            kv_cache=bc["cross"] if (bc is not None and mode == "decode") else None)
        x = x + h
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        x = constrain_batch(x + mlp_apply(bp["mlp"], h), ctx=ctx)
        nc = {"self": new_self, "cross": new_cross} \
            if mode in ("prefill", "decode") else None
        return x, nc

    body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    bcaches = caches["dec"] if caches is not None else None
    x, new_bc = jax.lax.scan(body_fn, x, (params["decoder"], bcaches))
    if mode == "prefill":
        x = x[:, -1:]  # last-position logits only (see lm.forward)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_apply(params["embed"], x)
    new_caches = None
    if mode in ("prefill", "decode"):
        new_caches = {"dec": new_bc,
                      "pos": (pos + 1) if mode == "decode"
                      else jnp.asarray(S, jnp.int32)}
    return logits, new_caches


def loss_fn(cfg, params, batch, ctx: MeshContext = None) -> jax.Array:
    enc_out = encode(cfg, params, batch["enc_embeds"], ctx=ctx)
    logits, _ = decode_stack(cfg, params, batch["tokens"], enc_out,
                             mode="train", ctx=ctx)
    return cross_entropy(logits, batch["labels"])


def make_train_step(cfg, optimizer, accum_steps: int = 1,
                    ctx: MeshContext = None, donate: bool = False,
                    dp_reduce=None, shardings=None, loss=None,
                    taps: bool = False):
    """``donate=True`` jits with ``donate_argnums=(0, 1)`` — same
    single-buffered params/opt-state contract as ``lm.make_train_step``;
    ``dp_reduce`` switches to the mesh-aware sharded path (shard_map DP
    gradient reduction — see ``lm.make_sharded_train_step``) with this
    module's encoder-decoder loss; ``loss=`` swaps the objective (the
    LoRA merged-forward path); ``taps=True`` adds the optimizer's
    per-bucket observability scalars as ``metrics["taps"]`` (same
    contract as ``lm.make_train_step``, DESIGN.md §12)."""
    from repro.models.lm import make_sharded_train_step, microbatch_split
    loss = loss_fn if loss is None else loss
    if isinstance(dp_reduce, str):
        from repro.distributed.compression import DPReduceSpec
        dp_reduce = DPReduceSpec.parse(dp_reduce)  # 'none' -> None
    if dp_reduce is not None:
        if taps:
            raise ValueError("taps=True is not supported on the sharded "
                             "dp_reduce path")
        return make_sharded_train_step(cfg, optimizer, loss, ctx=ctx,
                                       dp_reduce=dp_reduce,
                                       accum_steps=accum_steps,
                                       shardings=shardings, donate=donate)
    taps = taps and getattr(optimizer, "tapped_update", None) is not None

    def train_step(params, opt_state, batch):
        c = ctx if ctx is not None else MeshContext.ambient()
        micro = microbatch_split(batch, accum_steps, ctx=c)

        def accum_body(carry, mb):
            gsum, lsum = carry
            l, g = jax.value_and_grad(
                lambda p: loss(cfg, p, mb, ctx=c))(params)
            return (jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 gsum, g), lsum + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(accum_body, (g0, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: (g / accum_steps).astype(cfg.dtype), gsum)
        if taps:
            new_params, new_opt, tp = optimizer.tapped_update(
                grads, opt_state, params)
            return new_params, new_opt, {"loss": lsum / accum_steps,
                                         "taps": tp}
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": lsum / accum_steps}
    if donate:
        return jax.jit(train_step, donate_argnums=(0, 1))
    return train_step


def abstract_cache(cfg, B: int, max_len: int, enc_len: int):
    mk = _cache_maker("abstract", jnp.dtype(cfg.dtype))
    KV, hd = cfg.n_kv_heads, cfg.head_dim

    def one():
        return {"self": {"k": mk((B, max_len, KV, hd),
                                 ("batch", "seq", "kv_heads", None), None),
                         "v": mk((B, max_len, KV, hd),
                                 ("batch", "seq", "kv_heads", None), None)},
                "cross": {"k": mk((B, enc_len, KV, hd),
                                  ("batch", "seq", "kv_heads", None), None),
                          "v": mk((B, enc_len, KV, hd),
                                  ("batch", "seq", "kv_heads", None), None)}}

    dec = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
        (cfg.n_dec_layers,) + s.shape, s.dtype), one())
    return {"dec": dec, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_axes(cfg):
    mk = _cache_maker("axes", None)
    KV, hd = cfg.n_kv_heads, cfg.head_dim

    def one():
        return {"self": {"k": mk((), ("batch", "seq", "kv_heads", None), None),
                         "v": mk((), ("batch", "seq", "kv_heads", None), None)},
                "cross": {"k": mk((), ("batch", "seq", "kv_heads", None), None),
                          "v": mk((), ("batch", "seq", "kv_heads", None), None)}}

    dec = jax.tree.map(lambda a: Axes(("layers",) + a.names), one())
    return {"dec": dec, "pos": Axes(())}


def make_decode_step(cfg, ctx: MeshContext = None):
    def decode_step(params, caches, batch):
        logits, new_caches = decode_stack(cfg, params, batch["tokens"],
                                          enc_out=None, mode="decode",
                                          caches=caches, ctx=ctx)
        return logits[:, -1], new_caches
    return decode_step


def make_prefill_step(cfg, ctx: MeshContext = None):
    def prefill_step(params, batch):
        enc_out = encode(cfg, params, batch["enc_embeds"], ctx=ctx)
        logits, caches = decode_stack(cfg, params, batch["tokens"], enc_out,
                                      mode="prefill", ctx=ctx)
        return logits[:, -1], caches
    return prefill_step
