"""Decoder-only LM driver: parameter construction (init / logical-axes /
abstract via one Builder-driven code path), scan-over-periods stack,
train / prefill / decode steps.

The layer stack is ``lax.scan`` over *period groups* (DESIGN.md §7):
compile time and HLO size are O(1) in depth; the roofline analyzer
multiplies while-body costs by the trip count.
"""

from __future__ import annotations

import functools
import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks, rope as rope_lib
from repro.models.layers import (Axes, Builder, cross_entropy, embed_apply,
                                 embed_init, logits_apply, rms_norm, softcap,
                                 wsc as _wsc)
from repro.runtime.context import MeshContext

AUX_COEF = 0.01  # MoE load-balance loss weight


def _sqrt_group(n_periods: int) -> int:
    """Group size for two-level remat: the divisor of n closest to √n
    (1 = plain single-level scan; only used for deep stacks)."""
    if n_periods < 32:
        return 1
    best = 1
    for g in range(2, n_periods + 1):
        if n_periods % g == 0 and abs(g - math.isqrt(n_periods)) \
                < abs(best - math.isqrt(n_periods)):
            best = g
    return best if best > 1 else 1


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _stack(b: Builder, n: int, fn):
    """Stack ``n`` copies of ``fn(builder)`` along a leading 'layers' axis."""
    if b.mode == "init":
        keys = jax.random.split(b._next_key(), n)
        return jax.vmap(lambda k: fn(Builder("init", k, b.dtype)))(keys)
    one = fn(b)
    if b.mode == "axes":
        return jax.tree.map(lambda a: Axes(("layers",) + a.names), one)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), one)


def _build(cfg, mode: str, key=None):
    b = Builder(mode, key, jnp.dtype(cfg.dtype))
    p: Dict[str, Any] = {"embed": embed_init(b, cfg.vocab, cfg.d_model,
                                             cfg.tie_embeddings)}

    def period(bb: Builder):
        return {f"b{i}": blocks.block_init(bb, cfg, kind)
                for i, kind in enumerate(cfg.pattern)}

    if cfg.n_periods > 0:
        p["layers"] = _stack(b, cfg.n_periods, period)
    if cfg.rem_layers:
        p["rem"] = {f"b{i}": blocks.block_init(b, cfg, cfg.pattern[i])
                    for i in range(cfg.rem_layers)}
    p["final_norm"] = b.param((cfg.d_model,), (None,), init="zeros")
    return p


def init(cfg, key) -> Dict[str, Any]:
    return _build(cfg, "init", key)


def param_axes(cfg) -> Dict[str, Any]:
    return _build(cfg, "axes")


def abstract_params(cfg) -> Dict[str, Any]:
    return _build(cfg, "abstract")


def param_count(cfg) -> int:
    return sum(int(jnp.prod(jnp.asarray(l.shape)))
               for l in jax.tree.leaves(abstract_params(cfg)))


# ---------------------------------------------------------------------------
# KV / recurrent caches
# ---------------------------------------------------------------------------

def _cache_maker(mode: str, default_dtype):
    def mk(shape, axes, dtype):
        dtype = dtype or default_dtype
        if mode == "init":
            return jnp.zeros(shape, dtype)
        if mode == "axes":
            return Axes(tuple(axes))
        return jax.ShapeDtypeStruct(shape, dtype)
    return mk


def _build_cache(cfg, mode: str, B: int, max_len: int):
    mk = _cache_maker(mode, jnp.dtype(cfg.dtype))

    def period_cache():
        return {f"b{i}": blocks.block_cache(mk, cfg, kind, B, max_len)
                for i, kind in enumerate(cfg.pattern)}

    cache: Dict[str, Any] = {}
    if cfg.n_periods > 0:
        one = period_cache()
        if mode == "axes":
            cache["layers"] = jax.tree.map(
                lambda a: Axes(("layers",) + a.names), one)
        elif mode == "abstract":
            cache["layers"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((cfg.n_periods,) + s.shape,
                                               s.dtype), one)
        else:
            cache["layers"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape).copy(), one)
    if cfg.rem_layers:
        cache["rem"] = {f"b{i}": blocks.block_cache(mk, cfg, cfg.pattern[i],
                                                    B, max_len)
                        for i in range(cfg.rem_layers)}
    if mode == "axes":
        cache["pos"] = Axes(())
    elif mode == "abstract":
        cache["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        cache["pos"] = jnp.zeros((), jnp.int32)
    return cache


def init_cache(cfg, B: int, max_len: int):
    return _build_cache(cfg, "init", B, max_len)


def abstract_cache(cfg, B: int, max_len: int):
    return _build_cache(cfg, "abstract", B, max_len)


def cache_axes(cfg, B: int = 1, max_len: int = 2):
    return _build_cache(cfg, "axes", B, max_len)


def _build_paged_caches(cfg, mode: str, num_pages: int, page_size: int,
                        quant: Optional[str]):
    mk = _cache_maker(mode, jnp.dtype(cfg.dtype))

    def period_cache():
        return {f"b{i}": blocks.block_paged_cache(mk, cfg, kind, num_pages,
                                                  page_size, quant)
                for i, kind in enumerate(cfg.pattern)}

    cache: Dict[str, Any] = {}
    if cfg.n_periods > 0:
        one = period_cache()
        if mode == "abstract":
            cache["layers"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((cfg.n_periods,) + s.shape,
                                               s.dtype), one)
        else:
            cache["layers"] = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (cfg.n_periods,) + x.shape).copy(), one)
    if cfg.rem_layers:
        cache["rem"] = {f"b{i}": blocks.block_paged_cache(
            mk, cfg, cfg.pattern[i], num_pages, page_size, quant)
            for i in range(cfg.rem_layers)}
    return cache


def init_paged_caches(cfg, num_pages: int, page_size: int,
                      kv_quant: Optional[str] = None):
    """Shared serving arenas: one ``(num_pages, page_size, KV, hd)`` pool
    per K and V per block, stacked over scan periods exactly like the
    dense decode caches so the scan-carry path is reused unchanged.
    ``kv_quant='int8'`` swaps each pool for ``{"q": int8, "scale": f32}``
    (repro.serve.kv encodings).  No ``pos``/``page_table`` entries — the
    engine owns those and passes them per call."""
    return _build_paged_caches(cfg, "init", num_pages, page_size, kv_quant)


def abstract_paged_caches(cfg, num_pages: int, page_size: int,
                          kv_quant: Optional[str] = None):
    return _build_paged_caches(cfg, "abstract", num_pages, page_size,
                               kv_quant)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(cfg, params, tokens: jax.Array, *, mode: str = "train",
            caches=None, mrope_positions=None, ctx: MeshContext = None
            ) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (logits, new_caches, aux_loss).  ``ctx`` pins the mesh and
    kernel backend explicitly; ``None`` adopts the ambient mesh (CPU unit
    tests).

    Serving (paged) variant: when ``caches`` carries a ``"page_table"``
    entry, ``caches["layers"]`` holds shared page pools (repro.serve.kv),
    ``caches["pos"]`` is a per-slot length VECTOR, and two extra modes
    apply — ``decode`` scatters one token per slot into its pages, and
    ``chunk_prefill`` pages in one slot's (1, C) prompt chunk at global
    positions ``pos[0]..pos[0]+C-1`` and returns the FULL chunk logits
    (the engine needs the prompt-final position, which may land mid-chunk
    when the last chunk is padded).
    """
    if ctx is None:
        ctx = MeshContext.ambient()
    B, S = tokens.shape
    # SP residuals (see constrain_batch): measured a net LOSS on the 256-chip
    # dry-run (deepseek collective 34.8s -> 187s from involuntary resharding;
    # EXPERIMENTS.md §Perf hypothesis log) — opt-in only.
    seq_par = mode == "train" and os.environ.get("REPRO_SEQ_PARALLEL") == "1"
    x = constrain_batch(embed_apply(params["embed"], tokens, cfg.d_model),
                        seq=seq_par, ctx=ctx)
    pos = caches["pos"] if caches is not None else None
    page_table = caches.get("page_table") if caches is not None else None

    if page_table is not None:
        if mode not in ("decode", "chunk_prefill"):
            raise ValueError(f"paged caches serve decode/chunk_prefill "
                             f"only, got mode={mode!r}")
        # per-slot positions: each slot rotates at its OWN fill level
        positions = pos[:, None] + (jnp.arange(S)[None, :]
                                    if mode == "chunk_prefill" else 0)
    elif mode == "decode":
        positions = jnp.broadcast_to(pos, (B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.mrope_sections:
        if mrope_positions is None:
            mrope_positions = jnp.broadcast_to(positions, (3, B, S))
        cos, sin = rope_lib.mrope_angles(mrope_positions, cfg.head_dim,
                                         cfg.rope_theta, cfg.mrope_sections)
    else:
        cos, sin = rope_lib.rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}

    def apply_period(x, pparams, pcache, pattern):
        new_pc = {}
        aux_sum = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            c = pcache[f"b{i}"] if pcache is not None else None

            def one_block(bp, xx, cc, kind=kind):
                return blocks.block_apply(bp, cfg, kind, xx, cos, sin,
                                          mode=mode, cache=cc, pos=pos,
                                          page_table=page_table)
            if cfg.remat and mode == "train" and len(pattern) > 1:
                # layer-level nested remat: the period-level backward
                # otherwise keeps ALL blocks' recomputed intermediates live
                # (measured 28 GiB on Jamba's 8-layer period w/ 4 MoE blocks)
                one_block = jax.checkpoint(one_block)
            x, nc, aux = one_block(pparams[f"b{i}"], x, c)
            x = constrain_batch(x, seq=seq_par, ctx=ctx)
            new_pc[f"b{i}"] = nc
            aux_sum = aux_sum + aux
        return x, new_pc, aux_sum

    if cfg.n_periods > 0 and mode in ("decode", "chunk_prefill") \
            and caches is not None:
        # Decode: the cache rides the scan CARRY (in-place donation-friendly
        # aliasing); as xs/ys the stacked cache cannot alias through the
        # while loop — measured +cache-size temp (16 GiB on deepseek
        # decode_32k; EXPERIMENTS.md §Perf).
        def dec_body(carry, xs):
            x, aux, cache_st = carry
            pparams, idx = xs
            pcache = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                       keepdims=False),
                cache_st)
            x, new_pc, aux_p = apply_period(x, pparams, pcache, cfg.pattern)
            cache_st = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), idx, 0), cache_st, new_pc)
            return (x, aux + aux_p, cache_st), None

        (x, aux_total, new_stacked), _ = jax.lax.scan(
            dec_body, (x, aux_total, caches["layers"]),
            (params["layers"], jnp.arange(cfg.n_periods)))
        new_caches["layers"] = new_stacked
    elif cfg.n_periods > 0:
        def body(carry, xs):
            x, aux = carry
            pparams, pcache = xs
            x, new_pc, aux_p = apply_period(x, pparams, pcache, cfg.pattern)
            return (x, aux + aux_p), new_pc

        body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
        pcaches = caches["layers"] if caches is not None else None
        xs = (params["layers"], pcaches)
        group = _sqrt_group(cfg.n_periods) if (cfg.remat and mode == "train") \
            else 1
        if group > 1:
            # two-level (√n) remat: only n/G outer boundaries stay live
            # through the backward pass; inner saves are G-bounded transients.
            def outer_body(carry, xs_g):
                # the inner body is checkpointed too: otherwise the inner
                # scan's AD saves ALL group members' layer intermediates
                # during the outer-group backward (measured 16 GiB on
                # qwen2-vl's group of 8 × ~2 GiB/layer).
                return jax.lax.scan(jax.checkpoint(body), carry, xs_g)

            outer_fn = jax.checkpoint(outer_body)
            xs_g = jax.tree.map(
                lambda a: a.reshape((cfg.n_periods // group, group)
                                    + a.shape[1:]), xs)
            (x, aux_total), stacked_pc = jax.lax.scan(
                outer_fn, (x, aux_total), xs_g)
            stacked_pc = jax.tree.map(
                lambda a: a.reshape((cfg.n_periods,) + a.shape[2:]),
                stacked_pc)
        else:
            (x, aux_total), stacked_pc = jax.lax.scan(body_fn, (x, aux_total),
                                                      xs)
        new_caches["layers"] = stacked_pc

    if cfg.rem_layers:
        rc = caches["rem"] if caches is not None else None
        x, new_rc, aux_r = apply_period(x, params["rem"], rc,
                                        cfg.pattern[:cfg.rem_layers])
        aux_total = aux_total + aux_r
        new_caches["rem"] = new_rc

    if mode == "prefill":
        x = x[:, -1:]  # only the last position's logits are consumed —
        # full-sequence logits at 32k×(unsharded 256k vocab) cost 33 GiB/dev
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_apply(params["embed"], x)
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    if caches is not None:
        inc = 1 if mode == "decode" else (S if mode == "chunk_prefill" else 0)
        new_caches["pos"] = pos + inc
        if page_table is not None:
            new_caches["page_table"] = page_table
        return logits, new_caches, aux_total
    if mode == "prefill":
        new_caches["pos"] = jnp.asarray(S, jnp.int32)
        return logits, new_caches, aux_total
    return logits, None, aux_total


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, batch, ctx: MeshContext = None) -> jax.Array:
    logits, _, aux = forward(cfg, params, batch["tokens"], mode="train",
                             mrope_positions=batch.get("mrope_positions"),
                             ctx=ctx)
    return cross_entropy(logits, batch["labels"]) + AUX_COEF * aux


def constrain_batch(x, bdim: int = 0, seq: bool = False, seq_dim: int = 1,
                    ctx: MeshContext = None):
    """Pin the batch dim of an activation to the DP axes (no-op if absent).

    ``seq=True`` additionally shards the sequence dim over 'model'
    (Megatron-style sequence parallelism): applied at *period boundaries*
    so the scan-carry residuals — the dominant live-range at depth 95 —
    are 16× smaller; XLA re-gathers at the next block's matmuls, turning
    the TP all-reduce into all-gather + reduce-scatter (same wire bytes).
    """
    if ctx is None:
        ctx = MeshContext.ambient()
    if not ctx.axis_names:
        return x
    dp = ctx.dp_axes(x.shape[bdim])
    spec = [None] * x.ndim
    if dp is not None:
        spec[bdim] = dp
    if seq and ctx.has_axis("model") \
            and x.shape[seq_dim] % ctx.axis_size("model") == 0:
        spec[seq_dim] = "model"
    if all(s is None for s in spec):
        return x
    return _wsc(x, *spec, ctx=ctx)


def microbatch_split(batch: Dict[str, jax.Array], accum: int,
                     ctx: MeshContext = None) -> Dict[str, jax.Array]:
    """Split the global batch into ``accum`` microbatches with a
    *shard-preserving* layout: ``(B,) -> (mb, accum) -> swap -> (accum, mb)``
    maps microbatch ``a``, row ``m`` to global row ``m·accum + a`` — each
    device keeps exactly its own rows, so the split inserts ZERO collectives
    (a dynamic_slice along the data-sharded dim would gather the batch —
    measured 16× per-device inflation; see EXPERIMENTS.md §Dry-run notes).
    """
    out = {}
    for k, v in batch.items():
        if k == "mrope_positions":                   # (3, B, S): batch dim 1
            mb = v.shape[1] // accum
            r = v.reshape(3, mb, accum, v.shape[2]).transpose(2, 0, 1, 3)
            out[k] = _wsc(r, None, None, "data", None, ctx=ctx)  # (accum, 3, mb, S)
        else:                                        # (B, ...)
            mb = v.shape[0] // accum
            r = v.reshape(mb, accum, *v.shape[1:]).swapaxes(0, 1)
            out[k] = _wsc(r, None, "data", *([None] * (v.ndim - 1)), ctx=ctx)
    return out


def _contiguous_microbatches(batch: Dict[str, jax.Array], accum: int
                             ) -> Dict[str, jax.Array]:
    """Split a (device-local) batch into ``accum`` CONTIGUOUS row blocks:
    ``(B,) -> (accum, B/accum)``.  Inside ``shard_map`` the data is already
    local, so — unlike :func:`microbatch_split`'s strided shard-preserving
    layout — contiguity costs nothing, and it is what makes the logical
    shard grid independent of the device count: shard ``s`` always holds
    global rows ``[s·B/S, (s+1)·B/S)`` whether ``s`` indexes a device, an
    accumulation step, or a mix."""
    out = {}
    for k, v in batch.items():
        if k == "mrope_positions":                   # (3, B, S): batch dim 1
            if v.shape[1] % accum:
                raise ValueError(f"local batch {v.shape[1]} not divisible "
                                 f"by accum_steps={accum}")
            mb = v.shape[1] // accum
            out[k] = v.reshape(3, accum, mb, v.shape[2]).transpose(1, 0, 2, 3)
        else:                                        # (B, ...)
            if v.shape[0] % accum:
                raise ValueError(f"local batch {v.shape[0]} not divisible "
                                 f"by accum_steps={accum}")
            mb = v.shape[0] // accum
            out[k] = v.reshape(accum, mb, *v.shape[1:])
    return out


def make_sharded_train_step(cfg, optimizer, loss, *, ctx: MeshContext,
                            dp_reduce, accum_steps: int = 1, shardings=None,
                            donate: bool = False):
    """Mesh-aware train step: the data-parallel gradient reduction runs
    *manually* — per-device gradients inside ``shard_map`` over the DP
    axes, reduced by :func:`repro.distributed.compression
    .compressed_psum_mean` (exact f32 ``psum`` when
    ``dp_reduce.detail_dtype is None``; wavelet-compressed otherwise).
    Everything outside the shard_map (optimizer update, constraint
    pinning) stays under GSPMD; a 'model' axis, if present, is left to
    GSPMD *inside* too (shard_map auto axes), so TP composes.

    Numerics contract: the gradient is the mean over ``dp_size ×
    accum_steps`` contiguous logical shards, per-shard grads summed
    shard-order-sequentially (the accumulation scan within a device, the
    device-order ``psum`` across).  Because the CPU/TPU all-reduce sums in
    device order, a run on D devices with accum A is *bitwise* equal to a
    run on 1 device with accum D·A when A == 1 — the topology-equivalence
    tier in tests/test_sharded_train.py pins exactly that.

    ``shardings`` (a :class:`repro.distributed.sharding.StepShardings`)
    pins inputs and outputs: batch to its DP layout, params/opt_state to
    the FSDP layout (or replicated).  ``donate=True`` jits with
    ``donate_argnums=(0, 1)`` exactly like the auto-sharded step.

    Pure-DP meshes only: leaving a TP 'model' axis to GSPMD as a
    shard_map *auto* axis miscompiles on the pinned jax/XLA 0.4.x (hard
    ``IsManualSubgroup`` check abort in hlo_sharding_util once the real
    model graph is inside) — rejected here with a real error instead.
    TP meshes keep the auto-sharded step (``dp_reduce=None``).
    """
    from repro.distributed import compression
    if isinstance(dp_reduce, str):
        dp_reduce = compression.DPReduceSpec.parse(dp_reduce)
    if dp_reduce is None:
        raise ValueError("dp_reduce None/'none' means the auto-sharded "
                         "step — call make_train_step, which routes here "
                         "only for a real DPReduceSpec")
    if ctx is None or ctx.mesh is None or not ctx.dp_axis_names:
        raise ValueError("make_sharded_train_step needs a MeshContext with "
                         "a 'data' axis (use make_mesh_context)")
    if ctx.auto_axis_names:
        raise ValueError(
            f"dp_reduce needs a pure-DP mesh (('data',) or ('pod', "
            f"'data')), got axes {ctx.axis_names}: leaving "
            f"{ctx.auto_axis_names} to GSPMD inside shard_map trips an "
            f"XLA manual-subgroup check on the pinned jax 0.4.x — use "
            f"dp_reduce=None (auto-sharded step) for TP meshes")
    dp_axes = ctx.dp_axis_names
    axis = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    dp_size = ctx.dp_size
    # error feedback (DESIGN.md §3 / --dp-error-feedback): each device
    # keeps the residue its detail-band quantization discarded and adds it
    # back next step.  The residue is per-device state, carried OUTSIDE the
    # optimizer as ``opt_state = {"opt": <real>, "dp_ef": <residue>}``
    # (leaves ``(dp_size, *param_shape)`` f32, sharded over the DP axis) —
    # see ``compression.ef_init`` / ``ef_state_shardings``.
    ef_on = bool(getattr(dp_reduce, "error_feedback", False)) \
        and not dp_reduce.exact
    # inside the manual region every sharding constraint must be a no-op:
    # hand the forward a mesh-less context instead of letting wsc degrade
    inner_ctx = MeshContext(mesh=None, kernel_impl=ctx.kernel_impl)
    # wavelet split of the wire reduction follows the session's kernel
    # backend: pallas/interpret fuses the detail quantize into the DWT
    # launch (compression.reduce_terms impl kwarg)
    from repro import compat
    wire_impl = compat.resolve_kernel_impl(ctx.kernel_impl or "auto")

    def batch_spec(k: str, v) -> jax.sharding.PartitionSpec:
        bdim = 1 if k == "mrope_positions" else 0
        spec = [None] * v.ndim
        spec[bdim] = axis
        return jax.sharding.PartitionSpec(*spec)

    def local_grads(params, lbatch, ef=None):
        micro = _contiguous_microbatches(lbatch, accum_steps)

        def body(carry, mb):
            gsum, lsum = carry
            l, g = jax.value_and_grad(
                lambda p: loss(cfg, p, mb, ctx=inner_ctx))(params)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                gsum, g)
            return (gsum, lsum + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), micro)
        gmean = jax.tree.map(lambda a: a / accum_steps, gsum)
        lmean = jax.lax.psum(lsum / accum_steps, axis) / dp_size
        if not ef_on:
            grads = jax.tree.map(
                functools.partial(compression.compressed_psum_mean,
                                  axis_name=axis, level=dp_reduce.level,
                                  detail_dtype=dp_reduce.detail_dtype,
                                  impl=wire_impl), gmean)
            return grads, lmean
        g_leaves, treedef = jax.tree.flatten(gmean)
        e_leaves = treedef.flatten_up_to(ef)
        pairs = [compression.compressed_psum_mean_ef(
            g, e[0], axis_name=axis, level=dp_reduce.level,
            detail_dtype=dp_reduce.detail_dtype, impl=wire_impl)
            for g, e in zip(g_leaves, e_leaves)]
        grads = jax.tree_util.tree_unflatten(treedef,
                                             [p[0] for p in pairs])
        new_ef = jax.tree_util.tree_unflatten(treedef,
                                              [p[1][None] for p in pairs])
        return grads, lmean, new_ef

    def train_step(params, opt_state, batch):
        ef_state = None
        if ef_on:
            if not (isinstance(opt_state, dict)
                    and set(opt_state) == {"opt", "dp_ef"}):
                raise ValueError(
                    "error-feedback train step expects opt_state = "
                    "{'opt': <optimizer state>, 'dp_ef': "
                    "compression.ef_init(params, dp_size)}")
            ef_state, opt_state = opt_state["dp_ef"], opt_state["opt"]
        if shardings is not None:
            params = jax.tree.map(jax.lax.with_sharding_constraint,
                                  params, shardings.params)
            if shardings.opt is not None:
                opt_state = jax.tree.map(jax.lax.with_sharding_constraint,
                                         opt_state, shardings.opt)
            batch = {k: jax.lax.with_sharding_constraint(v,
                                                         shardings.batch[k])
                     for k, v in batch.items()}
        from repro import compat
        P = jax.sharding.PartitionSpec
        param_specs = jax.tree.map(lambda _: P(), params)
        in_specs = (param_specs,
                    {k: batch_spec(k, v) for k, v in batch.items()})
        out_specs = (param_specs, P())
        args = (params, batch)
        if ef_on:
            ef_specs = jax.tree.map(
                lambda e: P(axis, *([None] * (e.ndim - 1))), ef_state)
            in_specs += (ef_specs,)
            out_specs += (ef_specs,)
            args += (ef_state,)
        fn = compat.shard_map(local_grads, ctx.mesh,
                              in_specs=in_specs, out_specs=out_specs)
        if ef_on:
            grads, loss_mean, new_ef = fn(*args)
        else:
            grads, loss_mean = fn(*args)
        grads = jax.tree.map(lambda g: g.astype(cfg.dtype), grads)
        if shardings is not None:
            # pin the (replicated) reduced grads to the parameter layout so
            # the update partitions like the state it writes
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, shardings.params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        if shardings is not None:
            new_params = jax.tree.map(jax.lax.with_sharding_constraint,
                                      new_params, shardings.params)
            if shardings.opt is not None:
                new_opt = jax.tree.map(jax.lax.with_sharding_constraint,
                                       new_opt, shardings.opt)
        if ef_on:
            new_opt = {"opt": new_opt, "dp_ef": new_ef}
        return new_params, new_opt, {"loss": loss_mean}

    if donate:
        return jax.jit(train_step, donate_argnums=(0, 1))
    return train_step


def make_train_step(cfg, optimizer, accum_steps: int = 1,
                    grad_shardings=None, ctx: MeshContext = None,
                    donate: bool = False, dp_reduce=None, shardings=None,
                    loss=None, taps: bool = False):
    """Gradient-accumulated train step: ``batch`` is the GLOBAL batch; a
    shard-preserving reshape feeds a microbatch ``lax.scan``.

    ``dp_reduce`` (a ``repro.distributed.compression.DPReduceSpec`` or
    ``'exact'`` / ``'compressed'``) switches to the mesh-aware sharded
    path — see :func:`make_sharded_train_step`; ``shardings`` rides along
    to pin params/opt_state/batch placement.

    ``grad_shardings`` (optional NamedSharding tree like params): pins each
    microbatch's bf16 gradients to the parameter sharding *before* the f32
    accumulation — the cross-data reduce-scatter then moves bf16, not f32
    (half the dominant DP wire bytes), and the f32 accumulator itself is
    fully sharded.

    ``donate=True`` returns the step already jitted with
    ``donate_argnums=(0, 1)``: XLA aliases the ``(params, opt_state)``
    input buffers into the outputs, so params + optimizer state stay
    single-buffered across steps instead of double-buffered (~2× peak
    state memory without it).  The caller must rebind, not reuse, the
    arrays it passes in.  ``donate=False`` keeps the historical behaviour
    of returning the raw traceable function.

    ``taps=True`` routes the update through the optimizer's
    ``tapped_update`` channel (``repro.optim.engine``; DESIGN.md §12) and
    adds the per-bucket observability scalars to the metrics dict as
    ``metrics["taps"]`` — same trace, no extra launches.  Ignored (with
    tap-free metrics) when the optimizer exposes no tapped channel; not
    threaded through the sharded ``dp_reduce`` path.
    """
    loss = loss_fn if loss is None else loss  # `loss=`: swap the objective
    if isinstance(dp_reduce, str):
        from repro.distributed.compression import DPReduceSpec
        dp_reduce = DPReduceSpec.parse(dp_reduce)  # 'none' -> None
    if dp_reduce is not None:
        if taps:
            raise ValueError("taps=True is not supported on the sharded "
                             "dp_reduce path — run taps-off or drop "
                             "dp_reduce")
        return make_sharded_train_step(cfg, optimizer, loss, ctx=ctx,
                                       dp_reduce=dp_reduce,
                                       accum_steps=accum_steps,
                                       shardings=shardings, donate=donate)
    taps = taps and getattr(optimizer, "tapped_update", None) is not None

    def train_step(params, opt_state, batch):
        # resolve the ambient fallback at trace time, not build time: the
        # launcher may build the step outside the mesh context and jit it in
        c = ctx if ctx is not None else MeshContext.ambient()
        micro = microbatch_split(batch, accum_steps, ctx=c)

        def accum_body(carry, mb):
            gsum, lsum = carry
            l, g = jax.value_and_grad(
                lambda p: loss(cfg, p, mb, ctx=c))(params)
            if grad_shardings is not None:
                g = jax.tree.map(jax.lax.with_sharding_constraint, g,
                                 grad_shardings)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if grad_shardings is not None:
            g0 = jax.tree.map(jax.lax.with_sharding_constraint, g0,
                              grad_shardings)
        (gsum, lsum), _ = jax.lax.scan(accum_body, (g0, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: (g / accum_steps).astype(cfg.dtype), gsum)
        if taps:
            new_params, new_opt, tp = optimizer.tapped_update(
                grads, opt_state, params)
            return new_params, new_opt, {"loss": lsum / accum_steps,
                                         "taps": tp}
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": lsum / accum_steps}

    if donate:
        return jax.jit(train_step, donate_argnums=(0, 1))
    return train_step


def make_prefill_step(cfg, ctx: MeshContext = None):
    def prefill_step(params, batch):
        logits, caches, _ = forward(cfg, params, batch["tokens"],
                                    mode="prefill",
                                    mrope_positions=batch.get("mrope_positions"),
                                    ctx=ctx)
        return logits[:, -1], caches
    return prefill_step


def make_decode_step(cfg, ctx: MeshContext = None):
    def decode_step(params, caches, batch):
        logits, new_caches, _ = forward(
            cfg, params, batch["tokens"], mode="decode", caches=caches,
            mrope_positions=batch.get("mrope_positions"), ctx=ctx)
        return logits[:, -1], new_caches
    return decode_step


def make_paged_decode_step(cfg, ctx: MeshContext = None):
    """One serving decode tick: ``tokens (num_slots, 1)`` — every slot,
    every tick (fixed shape for jit; inactive slots carry trash-page
    tables and get masked out by ``kv_valid``).  Returns
    ``(last-position logits (num_slots, V), new_pools)`` — the pools are
    the only mutated state, so the engine jits this with
    ``donate_argnums=(1,)`` and rebinds."""
    def step(params, pools, page_table, lens, tokens):
        caches = dict(pools)
        caches["pos"] = lens
        caches["page_table"] = page_table
        logits, new_caches, _ = forward(cfg, params, tokens, mode="decode",
                                        caches=caches, ctx=ctx)
        return logits[:, -1], {k: new_caches[k] for k in pools}
    return step


def make_chunk_prefill_step(cfg, ctx: MeshContext = None):
    """Page in ONE slot's next prompt chunk: ``tokens (1, C)`` at global
    positions ``filled[0]..filled[0]+C-1`` (``page_table`` is that slot's
    single row, ``(1, max_pages)``).  Returns the full ``(1, C, V)`` chunk
    logits plus the updated pools — same donation contract as the decode
    step."""
    def step(params, pools, page_table, filled, tokens):
        caches = dict(pools)
        caches["pos"] = filled
        caches["page_table"] = page_table
        logits, new_caches, _ = forward(cfg, params, tokens,
                                        mode="chunk_prefill", caches=caches,
                                        ctx=ctx)
        return logits, {k: new_caches[k] for k in pools}
    return step
