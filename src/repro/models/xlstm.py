"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix-memory, parallelizable)
and sLSTM (scalar-memory, recurrent with exponential gating).

mLSTM training/prefill uses the stabilized *parallel* (quadratic) form —
attention-like, TPU/MXU-friendly; decode uses the recurrent matrix-memory
update (O(1) state ⇒ long_500k eligible).  sLSTM is inherently sequential:
``lax.scan`` over time (its block-diagonal per-head recurrence is tiny).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Builder, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(b: Builder, cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    di = 2 * d                       # xLSTM up-projection factor 2
    k = cfg.ssm_conv
    return {
        "up_proj": b.param((d, 2 * di), ("embed", "inner")),
        "conv_w": b.param((k, di), (None, "inner"), scale=0.5),
        "conv_b": b.param((di,), ("inner",), init="zeros"),
        "wq": b.param((di, di), ("inner", "heads")),
        "wk": b.param((di, di), ("inner", "heads")),
        "wv": b.param((di, di), ("inner", "heads")),
        "w_igate": b.param((di, H), ("inner", None), scale=0.01),
        "b_igate": b.param((H,), (None,), init="zeros"),
        "w_fgate": b.param((di, H), ("inner", None), scale=0.01),
        "b_fgate": b.param((H,), (None,), init="ones"),
        "out_norm": b.param((di,), ("inner",), init="zeros"),
        "down_proj": b.param((di, d), ("inner", "embed")),
    }


def _mlstm_parallel(q, k, v, log_i, log_f):
    """Stabilized parallel mLSTM.  q,k,v (B,T,H,dh); gates (B,T,H)."""
    B, T, H, dh = q.shape
    logsig_f = jax.nn.log_sigmoid(log_f.astype(jnp.float32))
    F = jnp.cumsum(logsig_f, axis=1)                          # (B,T,H)
    # D[t,s] = F_t - F_s + i_s  for s <= t
    D = F[:, :, None] - F[:, None, :] + log_i.astype(jnp.float32)[:, None, :]
    tpos = jnp.arange(T)                                      # D: (B,T,S,H)
    D = jnp.where((tpos[:, None] >= tpos[None, :])[None, :, :, None],
                  D, -jnp.inf)
    m = jnp.max(D, axis=2, keepdims=True)                     # (B,T,1,H)
    m = jnp.maximum(m, 0.0)
    W = jnp.exp(D - m)                                        # (B,T,S,H)
    s = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    sw = s * W
    n = jnp.maximum(jnp.abs(sw.sum(2, keepdims=True)), jnp.exp(-m))
    h = jnp.einsum("btsh,bshd->bthd", sw / n, v.astype(jnp.float32))
    return h.astype(q.dtype)


_MLSTM_CHUNK = 1024


def _mlstm_chunkwise(q, k, v, log_i, log_f, chunk: int = _MLSTM_CHUNK):
    """Chunkwise-parallel mLSTM (GLA/xLSTM chunk kernels, stabilized).

    Exact: within-chunk quadratic (``chunk²`` tile) + inter-chunk matrix
    memory carried recurrently.  Unchunked, the (B,T,T,H) decay matrix at
    prefill_32k is ~4 TiB — the chunkwise form bounds it to (B,c,c,H).

    Returns (h (B,T,H,dh), final (C', n', m) state with C' stabilized by m).
    """
    B, T, H, dh = q.shape
    nc = T // chunk
    assert T % chunk == 0, (T, chunk)
    q32 = q.astype(jnp.float32)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    li = log_i.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(log_f.astype(jnp.float32))

    def reshape_c(x):
        return x.reshape(B, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, lis, lfs = map(reshape_c, (q32, k32, v32, li, lf))

    def chunk_step(carry, xs):
        C0, n0, m0 = carry                     # C0,n0 stabilized by m0
        qc, kc, vc, lic, lfc = xs              # (B,c,H,*) / (B,c,H)
        ksc = kc / np.sqrt(dh)                 # decode-path convention
        F = jnp.cumsum(lfc, axis=1)            # (B,c,H)
        # intra-chunk decay matrix D[t,s] = F_t - F_s + i_s (s<=t)
        D = F[:, :, None] - F[:, None, :] + lic[:, None, :]
        tpos = jnp.arange(chunk)
        causal = (tpos[:, None] >= tpos[None, :])[None, :, :, None]
        D = jnp.where(causal, D, -jnp.inf)
        inter_log = F + m0[:, None]            # weight of C0 at position t
        m = jnp.maximum(jnp.max(D, axis=2), inter_log)   # (B,c,H)
        m = jnp.maximum(m, 0.0)
        W = jnp.exp(D - m[:, :, None])                   # (B,c,c,H)
        s = jnp.einsum("bthd,bshd->btsh", qc, ksc)
        sw = s * W
        inter_w = jnp.exp(inter_log - m)                 # (B,c,H)
        num = jnp.einsum("btsh,bshd->bthd", sw, vc) \
            + inter_w[..., None] * jnp.einsum("bthd,bhde->bthe", qc, C0)
        den = jnp.abs(sw.sum(2) + inter_w *
                      jnp.einsum("bthd,bhd->bth", qc, n0))
        den = jnp.maximum(den, jnp.exp(-m))
        h = num / den[..., None]
        # end-of-chunk state under the new stabilizer m_end
        Ftot = F[:, -1]                                   # (B,H)
        decay_s = Ftot[:, None] - F + lic                 # (B,c,H)
        m_end = jnp.maximum(Ftot + m0, jnp.max(decay_s, axis=1))
        wgt = jnp.exp(decay_s - m_end[:, None])           # (B,c,H)
        C_new = jnp.exp(Ftot + m0 - m_end)[..., None, None] * C0 \
            + jnp.einsum("bsh,bshd,bshe->bhde", wgt, ksc, vc)
        n_new = jnp.exp(Ftot + m0 - m_end)[..., None] * n0 \
            + jnp.einsum("bsh,bshd->bhd", wgt, ksc)
        return (C_new, n_new, m_end), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qs, ks, vs, lis, lfs))
    h = hs.swapaxes(0, 1).reshape(B, T, H, dh)
    return h.astype(q.dtype), (C, n, m)


def mlstm_apply(p, cfg, x: jax.Array, *, mode: str = "train",
                cache: Optional[dict] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    B, T, d = x.shape
    H = cfg.n_heads
    di = 2 * d
    dh = di // H
    xz = x @ p["up_proj"]
    xm, z = jnp.split(xz, 2, axis=-1)

    new_cache = None
    if mode == "decode":
        assert cache is not None and T == 1
        conv_win = jnp.concatenate([cache["conv"], xm], axis=1)
        xc = jax.nn.silu(
            sum(conv_win[:, i:i + 1] * p["conv_w"][i]
                for i in range(cfg.ssm_conv)) + p["conv_b"])
        q = (xc @ p["wq"]).reshape(B, 1, H, dh)[:, 0]
        k = (xc @ p["wk"]).reshape(B, 1, H, dh)[:, 0] / np.sqrt(dh)
        v = (xc @ p["wv"]).reshape(B, 1, H, dh)[:, 0]
        log_i = (xc[:, 0] @ p["w_igate"] + p["b_igate"]).astype(jnp.float32)
        log_f = jax.nn.log_sigmoid(
            (xc[:, 0] @ p["w_fgate"] + p["b_fgate"]).astype(jnp.float32))
        m_new = jnp.maximum(log_f + cache["m"], log_i)        # (B,H)
        i_s = jnp.exp(log_i - m_new)
        f_s = jnp.exp(log_f + cache["m"] - m_new)
        C = f_s[..., None, None] * cache["C"] + \
            i_s[..., None, None] * jnp.einsum(
                "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
        nvec = f_s[..., None] * cache["n"] + i_s[..., None] * k.astype(jnp.float32)
        num = jnp.einsum("bhde,bhd->bhe", C, q.astype(jnp.float32))
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", nvec,
                                             q.astype(jnp.float32))),
                          jnp.exp(-m_new))
        h = (num / den[..., None]).reshape(B, 1, di).astype(x.dtype)
        new_cache = {"C": C, "n": nvec, "m": m_new, "conv": conv_win[:, 1:]}
    else:
        xc = jax.nn.silu(
            sum(jnp.pad(xm, ((0, 0), (cfg.ssm_conv - 1 - i, 0), (0, 0)))[:, :T]
                * p["conv_w"][i] for i in range(cfg.ssm_conv)) + p["conv_b"])
        q = (xc @ p["wq"]).reshape(B, T, H, dh)
        k = (xc @ p["wk"]).reshape(B, T, H, dh)   # raw; forms scale internally
        v = (xc @ p["wv"]).reshape(B, T, H, dh)
        log_i = xc @ p["w_igate"] + p["b_igate"]
        log_f = xc @ p["w_fgate"] + p["b_fgate"]
        chunk = min(_MLSTM_CHUNK, T)
        if T % chunk:
            chunk = T
        h, (C, n, m) = _mlstm_chunkwise(q, k, v, log_i, log_f, chunk=chunk)
        h = h.reshape(B, T, di)
        if mode == "prefill":
            new_cache = {"C": C, "n": n, "m": m,
                         "conv": xm[:, -(cfg.ssm_conv - 1):]}

    h = rms_norm(h, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return h @ p["down_proj"], new_cache


def mlstm_cache(mk, cfg, B: int) -> dict:
    H = cfg.n_heads
    di = 2 * cfg.d_model
    dh = di // H
    return {"C": mk((B, H, dh, dh), ("batch", None, None, None), jnp.float32),
            "n": mk((B, H, dh), ("batch", None, None), jnp.float32),
            "m": mk((B, H), ("batch", None), jnp.float32),
            "conv": mk((B, cfg.ssm_conv - 1, di), ("batch", None, "inner"), None)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_ff(d: int) -> int:
    """xLSTM sLSTM post-MLP (proj factor 4/3), rounded to the 128-lane unit."""
    return ((4 * d // 3) + 127) // 128 * 128


def slstm_init(b: Builder, cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ff = _slstm_ff(d)
    return {
        "w": b.param((d, 4 * d), ("embed", "inner")),
        "r": b.param((H, dh, 4 * dh), (None, None, "inner"), scale=0.1),
        "b": b.param((4 * d,), ("inner",), init="zeros"),
        "out_norm": b.param((d,), (None,), init="zeros"),
        "up_gate": b.param((d, ff), ("embed", "mlp")),
        "up": b.param((d, ff), ("embed", "mlp")),
        "down": b.param((ff, d), ("mlp", "embed")),
    }


def _slstm_step(p, cfg, xt, state):
    """One sLSTM step. xt (B,d); state: c,n,h (B,H,dh), m (B,H,dh)."""
    B, d = xt.shape
    H = cfg.n_heads
    dh = d // H
    c, n, h, m = state
    wx = (xt @ p["w"]).reshape(B, H, 4 * dh)
    rh = jnp.einsum("bhd,hde->bhe", h, p["r"].astype(h.dtype))
    g = (wx + rh + p["b"].reshape(H, 4 * dh)).astype(jnp.float32)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)                 # (B,H,dh)
    m_new = jnp.maximum(gf + m, gi)                           # exp-gate stabilizer
    i_s = jnp.exp(gi - m_new)
    f_s = jnp.exp(gf + m - m_new)
    c = f_s * c + i_s * jnp.tanh(gz)
    n = f_s * n + i_s
    h_new = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new.astype(jnp.float32), m_new), h_new


def slstm_apply(p, cfg, x: jax.Array, *, mode: str = "train",
                cache: Optional[dict] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    B, T, d = x.shape
    H = cfg.n_heads
    dh = d // H
    if cache is not None:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        z = jnp.zeros((B, H, dh), jnp.float32)
        state = (z, z, z, jnp.full((B, H, dh), -1e30, jnp.float32))

    if mode == "decode":
        state, h = _slstm_step(p, cfg, x[:, 0], state)
        hs = h[:, None]
    else:
        def step(carry, xt):
            carry, h = _slstm_step(p, cfg, xt, carry)
            return carry, h
        state, hs = jax.lax.scan(step, state, x.swapaxes(0, 1))
        hs = hs.swapaxes(0, 1)                                # (B,T,H,dh)

    y = rms_norm(hs.reshape(B, -1, d).astype(x.dtype), p["out_norm"],
                 cfg.norm_eps)
    y = (jax.nn.silu(y @ p["up_gate"]) * (y @ p["up"])) @ p["down"]
    new_cache = None
    if mode in ("decode", "prefill"):
        c, n, h, m = state
        new_cache = {"c": c, "n": n, "h": h, "m": m}
    return y, new_cache


def slstm_cache(mk, cfg, B: int) -> dict:
    H = cfg.n_heads
    dh = cfg.d_model // H
    shp = (B, H, dh)
    ax = ("batch", None, None)
    return {"c": mk(shp, ax, jnp.float32), "n": mk(shp, ax, jnp.float32),
            "h": mk(shp, ax, jnp.float32), "m": mk(shp, ax, jnp.float32)}
