"""Rotary embeddings: standard RoPE + M-RoPE (Qwen2-VL 3-section rotary).

M-RoPE splits the head_dim rotary frequency bands into (temporal, height,
width) sections, each rotated by its own position id.  For text-only input
all three position streams coincide (the VLM frontend is a stub per the
assignment; the backbone math is faithful).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def rope_angles(positions: jax.Array, head_dim: int, theta: float
                ) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) -> cos/sin of shape (..., S, head_dim/2)."""
    freqs = jnp.asarray(_freqs(head_dim, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections: Tuple[int, ...]) -> Tuple[jax.Array, jax.Array]:
    """positions (3, B, S); sections sum to head_dim/2. Returns (B,S,hd/2)."""
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = jnp.asarray(_freqs(head_dim, theta), jnp.float32)
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (3,B,S,hd/2)
    chunks = []
    off = 0
    for i, sec in enumerate(sections):
        chunks.append(ang_all[i, ..., off:off + sec])
        off += sec
    ang = jnp.concatenate(chunks, axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
