"""Mamba selective-SSM block (Jamba's sequence mixer).

Training/prefill uses ``jax.lax.associative_scan`` over the diagonal SSM
recurrence (TPU-native replacement for the CUDA selective-scan kernel — the
recurrence ``h_t = a_t·h_{t-1} + b_t`` is associative with combine
``(a₁,b₁)∘(a₂,b₂) = (a₁a₂, a₂b₁+b₂)``).  Decode carries ``(h, conv window)``
state — O(1) per token, which is what qualifies the hybrid archs for the
``long_500k`` cell.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Builder


def _dt_rank(d_model: int) -> int:
    return max(1, int(np.ceil(d_model / 16)))


def mamba_init(b: Builder, cfg) -> dict:
    d, di, st, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dtr = _dt_rank(d)
    return {
        "in_proj": b.param((d, 2 * di), ("embed", "inner")),
        "conv_w": b.param((k, di), (None, "inner"), scale=0.5),
        "conv_b": b.param((di,), ("inner",), init="zeros"),
        "x_proj": b.param((di, dtr + 2 * st), ("inner", None)),
        "dt_proj": b.param((dtr, di), (None, "inner"), scale=0.1),
        "dt_bias": b.param((di,), ("inner",), init="zeros"),
        "a_log": b.param((di, st), ("inner", None), init="ones"),
        "d_skip": b.param((di,), ("inner",), init="ones"),
        "out_proj": b.param((di, d), ("inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv over time. x (B,T,C), w (k,C).
    ``prev`` (B,k-1,C): carried window for decode."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    T = x.shape[1]
    out = sum(xp[:, i:i + T] * w[i] for i in range(k))
    return out + bias


def _ssm_params(p, cfg, x):
    """x (B,T,di) -> (dA (B,T,di,st), dBx (B,T,di,st), C (B,T,st))."""
    st = cfg.ssm_state
    dtr = _dt_rank(cfg.d_model)
    proj = x @ p["x_proj"]
    dt_in, Bm, Cm = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])       # (B,T,di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                    # (di,st)
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)             # (B,T,di,st)
    dBx = (dt * x).astype(jnp.float32)[..., None] \
        * Bm.astype(jnp.float32)[:, :, None, :]                 # (B,T,di,st)
    return dA, dBx, Cm


_SCAN_CHUNK = 1024


def _selective_scan_chunked(p, cfg, xm_c: jax.Array
                            ) -> Tuple[jax.Array, jax.Array]:
    """Selective scan in sequence chunks: ``lax.scan`` carries the SSM state
    across chunks; ``associative_scan`` parallelizes within a chunk.

    The recurrence is linear, so chunking is EXACT — and it bounds the f32
    ``(B, chunk, d_inner, state)`` buffers to the chunk length.  Unchunked,
    prefill_32k materializes (B, 32768, d_inner, 16) f32 ≈ 8.6 GiB/layer per
    device (measured OOM against the 16 GiB budget; EXPERIMENTS.md §Dry-run).
    """
    B, T, di = xm_c.shape
    chunk = min(_SCAN_CHUNK, T)
    if T % chunk:
        chunk = T  # fallback: no clean chunking

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    @jax.checkpoint  # avoid stacking per-chunk (B,c,di,st) f32 AD residuals
    def chunk_step(h0, xc):
        dA, dBx, Cm = _ssm_params(p, cfg, xc)
        # fold the carried state into the first element: b'_1 = dA_1 h0 + b_1
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
        _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        yc = jnp.einsum("btds,bts->btd", hs, Cm.astype(jnp.float32))
        return hs[:, -1], yc

    if chunk == T:
        h_last, y = chunk_step(jnp.zeros((B, di, cfg.ssm_state), jnp.float32),
                               xm_c)
        return y, h_last
    xcs = xm_c.reshape(B, T // chunk, chunk, di).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(
        chunk_step, jnp.zeros((B, di, cfg.ssm_state), jnp.float32), xcs)
    y = ys.swapaxes(0, 1).reshape(B, T, -1)
    return y, h_last


def mamba_apply(p, cfg, x: jax.Array, *, mode: str = "train",
                cache: Optional[dict] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    B, T, _ = x.shape
    di = cfg.d_inner
    xz = x @ p["in_proj"]
    xm, z = jnp.split(xz, 2, axis=-1)

    new_cache = None
    if mode == "decode":
        assert cache is not None and T == 1
        conv_win = jnp.concatenate([cache["conv"], xm], axis=1)     # (B,k,di)
        xm_c = jax.nn.silu(_causal_conv(xm, p["conv_w"], p["conv_b"],
                                        prev=cache["conv"]))
        dA, dBx, Cm = _ssm_params(p, cfg, xm_c)
        h = dA[:, 0] * cache["h"] + dBx[:, 0]                        # (B,di,st)
        y = jnp.einsum("bds,bs->bd", h, Cm[:, 0].astype(jnp.float32))[:, None]
        new_cache = {"h": h, "conv": conv_win[:, 1:]}
    else:
        xm_c = jax.nn.silu(_causal_conv(xm, p["conv_w"], p["conv_b"]))
        y, h_last = _selective_scan_chunked(p, cfg, xm_c)
        if mode == "prefill":
            new_cache = {"h": h_last,
                         "conv": xm[:, -(cfg.ssm_conv - 1):]}

    y = (y + xm_c.astype(jnp.float32) * p["d_skip"].astype(jnp.float32))
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], new_cache


def mamba_cache(mk, cfg, B: int) -> dict:
    di, st, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {"h": mk((B, di, st), ("batch", "inner", None), jnp.float32),
            "conv": mk((B, k - 1, di), ("batch", None, "inner"), None)}
