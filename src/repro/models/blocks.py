"""Layer-block dispatcher: (mixer kind ∈ {attn, attn_local, mamba, mlstm,
slstm}) × (FFN ∈ {MLP, MoE, none}) with pre-norms and residuals.

A block kind string like ``"mamba+moe"`` selects the mamba mixer and swaps
the MLP for MoE (Jamba's every-other-layer MoE).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.models import attention, moe as moe_lib, ssm, xlstm
from repro.models.layers import Builder, mlp_init, mlp_apply, rms_norm


def parse_kind(kind: str) -> Tuple[str, bool]:
    base, *mods = kind.split("+")
    return base, "moe" in mods


def block_init(b: Builder, cfg, kind: str) -> dict:
    base, use_moe = parse_kind(kind)
    d = cfg.d_model
    p = {"norm1": b.param((d,), (None,), init="zeros")}
    if base in ("attn", "attn_local"):
        p["mixer"] = attention.attn_init(b, cfg)
    elif base == "mamba":
        p["mixer"] = ssm.mamba_init(b, cfg)
    elif base == "mlstm":
        p["mixer"] = xlstm.mlstm_init(b, cfg)
    elif base == "slstm":
        p["mixer"] = xlstm.slstm_init(b, cfg)
    else:
        raise ValueError(f"unknown block kind {base!r}")
    if use_moe:
        p["norm2"] = b.param((d,), (None,), init="zeros")
        p["ffn"] = moe_lib.moe_init(b, cfg)
    elif cfg.d_ff > 0:
        p["norm2"] = b.param((d,), (None,), init="zeros")
        p["ffn"] = mlp_init(b, d, cfg.d_ff)
    return p


def block_apply(p, cfg, kind: str, x, cos, sin, *, mode: str = "train",
                cache: Optional[dict] = None, pos=None,
                bidirectional: bool = False, page_table=None):
    """Returns (x, new_mixer_cache, aux_loss).  ``page_table`` selects the
    slot-paged serving cache layout (attention blocks only)."""
    base, use_moe = parse_kind(kind)
    if page_table is not None and base not in ("attn", "attn_local"):
        raise NotImplementedError(
            f"paged serving caches exist only for attention blocks, not "
            f"{base!r} (recurrent mixers keep O(1) state per slot and need "
            "no paging)")
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if base in ("attn", "attn_local"):
        h, nc = attention.attn_apply(
            p["mixer"], cfg, h, cos, sin, local=(base == "attn_local"),
            mode=mode, cache=cache, pos=pos, bidirectional=bidirectional,
            page_table=page_table)
    elif base == "mamba":
        h, nc = ssm.mamba_apply(p["mixer"], cfg, h, mode=mode, cache=cache)
    elif base == "mlstm":
        h, nc = xlstm.mlstm_apply(p["mixer"], cfg, h, mode=mode, cache=cache)
    else:
        h, nc = xlstm.slstm_apply(p["mixer"], cfg, h, mode=mode, cache=cache)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if use_moe:
            h, aux = moe_lib.moe_apply(p["ffn"], cfg, h)
        else:
            h = mlp_apply(p["ffn"], h)
        x = x + h
    return x, nc, aux


def block_cache(mk, cfg, kind: str, B: int, max_len: int) -> Optional[dict]:
    base, _ = parse_kind(kind)
    if base in ("attn", "attn_local"):
        local = base == "attn_local"
        size = min(cfg.window, max_len) if (local and cfg.window) else max_len
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        return {"k": mk((B, size, KV, hd), ("batch", "seq", "kv_heads", None), None),
                "v": mk((B, size, KV, hd), ("batch", "seq", "kv_heads", None), None)}
    if base == "mamba":
        return ssm.mamba_cache(mk, cfg, B)
    if base == "mlstm":
        return xlstm.mlstm_cache(mk, cfg, B)
    if base == "slstm":
        return xlstm.slstm_cache(mk, cfg, B)
    return None


def block_paged_cache(mk, cfg, kind: str, num_pages: int, page_size: int,
                      quant: Optional[str] = None) -> Optional[dict]:
    """Shared serving arena for one block: a page pool per K and V
    (repro.serve.kv layout), or ``None`` for cacheless blocks.  Only
    full-attention blocks are supported (the engine validates upstream)."""
    base, _ = parse_kind(kind)
    if base not in ("attn", "attn_local"):
        raise NotImplementedError(
            f"no paged cache layout for block kind {base!r}")
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    axes = ("pages", "page", "kv_heads", None)
    if quant == "int8":
        pool = lambda: {
            "q": mk((num_pages, page_size, KV, hd), axes, jnp.int8),
            "scale": mk((num_pages, page_size, KV), axes[:3], jnp.float32)}
    elif quant is None:
        pool = lambda: mk((num_pages, page_size, KV, hd), axes, None)
    else:
        raise ValueError(f"kv quant {quant!r}: expected None or 'int8'")
    return {"k": pool(), "v": pool()}
