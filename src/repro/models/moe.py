"""Mixture-of-Experts FFN: top-k softmax router + capacity-bounded
GShard-style one-hot einsum dispatch at token-CHUNK granularity
(TPU-native: static shapes, matmul-only dataflow, EP-shardable).

Design history (measured on the 256-chip dry-run, EXPERIMENTS.md §Perf):
* a GLOBAL (T,E,C) one-hot dispatch is O(T·K·E·C) — unusable at 128
  experts × 32k tokens;
* a scatter/gather dispatch is compact but its data-dependent destinations
  cannot be sharded by GSPMD — expert activations ended up REPLICATED
  per device (38 GiB on the all-MoE ablation);
* the committed design chunks tokens (scan, checkpointed bodies) and uses
  per-chunk (T_c,E,C_c) one-hot einsums: shardings propagate like any
  matmul, buffers scale with the chunk, and the dispatch FLOPs are the
  classic GShard tax (~+0.5× of expert compute at qwen3's shapes).

``expert_padding`` pads the expert WEIGHTS (router unchanged) so a 16-∤
expert count still EP-shards cleanly (qwen2-moe 60→64: 5.3× on the
dominant collective term for +6.7 % weights).

Supports shared (always-on) experts (Qwen-MoE) and returns the Switch-style
load-balancing auxiliary loss.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Builder, mlp_init, mlp_apply, wsc


def moe_init(b: Builder, cfg) -> dict:
    d, dff = cfg.d_model, cfg.d_ff_expert
    E = cfg.n_experts + cfg.expert_padding  # padded experts never routed
    p = {
        "router": b.param((d, cfg.n_experts), ("embed", None),
                          dtype=jnp.float32),
        "w_gate": b.param((E, d, dff), ("expert", "embed", "expert_mlp")),
        "w_up": b.param((E, d, dff), ("expert", "embed", "expert_mlp")),
        "w_down": b.param((E, dff, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(b, d, cfg.n_shared_experts * dff)
    return p


_MOE_CHUNK_TOKENS = 8192  # global tokens per dispatch chunk


def moe_apply(p, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar).

    Long sequences are processed in token chunks (scan): the dispatch
    buffers scale with the chunk, not the sequence — unchunked, the qwen3
    (128e top-8) prefill_32k cell allocates an (E·C, d) buffer ~40× the
    activation size (measured OOM; EXPERIMENTS.md §Dry-run).  Chunking is
    exact for the outputs; the Switch aux loss becomes a per-chunk average
    (documented deviation, gradient-equivalent in expectation).
    """
    B, S, d = x.shape
    total = B * S
    if total > _MOE_CHUNK_TOKENS and S % (_MOE_CHUNK_TOKENS // B or 1) == 0 \
            and _MOE_CHUNK_TOKENS >= B:
        sc = _MOE_CHUNK_TOKENS // B
        xcs = x.reshape(B, S // sc, sc, d).swapaxes(0, 1)

        # checkpointed chunk body: WITHOUT it the chunk scan's AD residuals
        # stack every chunk's (E,C,dff) expert activations — measured
        # ~24 GiB/dev on jamba train_4k (EXPERIMENTS.md §Perf).
        @jax.checkpoint
        def step_inner(xc):
            return _moe_dense(p, cfg, xc)

        def step(_, xc):
            out_c, aux_c = step_inner(xc)
            return None, (out_c, aux_c)

        _, (outs, auxs) = jax.lax.scan(step, None, xcs)
        return outs.swapaxes(0, 1).reshape(B, S, d), auxs.mean()
    return _moe_dense(p, cfg, x)


def _moe_dense(p, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    E_pad = E + cfg.expert_padding
    T = B * S
    xt = x.reshape(T, d)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                # renormalize

    C = max(1, math.ceil(T * K / E * cfg.capacity_factor))
    # slot of each (token, k) inside its expert's queue (order-preserving)
    onehot = jax.nn.one_hot(expert_idx.reshape(T * K), E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)                # (T·K, E)
    slot = jnp.take_along_axis(pos, expert_idx.reshape(T * K, 1), axis=1)[:, 0]
    slot = jnp.where(slot < C, slot, C).reshape(T, K)          # C = dropped

    # GShard-style einsum dispatch at CHUNK granularity.  (A scatter/gather
    # dispatch kept the expert activations replicated per device — GSPMD
    # cannot shard data-dependent scatter destinations — measured 38 GiB/dev
    # on the all-MoE ablation.  One-hot einsums propagate shardings like any
    # matmul; the (T,E,C) one-hots are small because T is the CHUNK size.)
    oh_e = (jax.nn.one_hot(expert_idx.reshape(T * K), E_pad, dtype=x.dtype)
            .reshape(T, K, E_pad))
    oh_c = jax.nn.one_hot(slot, C + 1, dtype=x.dtype)[..., :C]  # (T,K,C)
    disp = jnp.einsum("tke,tkc->tec", oh_e, oh_c)
    comb = jnp.einsum("tke,tkc,tk->tec", oh_e, oh_c,
                      gate_vals.astype(x.dtype))

    xe = wsc(jnp.einsum("td,tec->ecd", xt, disp), "model")      # EP-sharded
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = wsc(jnp.einsum("ecf,efd->ecd", h, p["w_down"]), "model")
    out = jnp.einsum("ecd,tec->td", ye, comb)

    # Switch aux loss: E · Σ_e f_e · P_e
    f = onehot.astype(jnp.float32).reshape(T, K, E).sum(1).mean(0)
    aux = E * jnp.sum(f * probs.mean(0))

    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], xt)
    return out.reshape(B, S, d), aux
