"""Attention substrate: GQA/MQA/MHA with sliding-window, logit softcap,
QKV-bias, QK-norm, KV caches (full + ring-buffer window), and three compute
paths chosen by static shape:

* direct einsum (short sequences),
* flash-style double-chunked online-softmax scan (long prefill — bounds the
  score tile to ``q_chunk × kv_chunk`` instead of ``S²``),
* block-local attention for sliding windows (reshape into window blocks;
  each block attends itself + its predecessor — exact, O(S·2w)).

Sharding note: all einsums keep the query-head axis ``H`` as a single dim
and explicitly repeat K/V to ``H`` heads (Megatron-style).  Keeping
``(KV, G)`` split would require a 2-axis tile assignment that GSPMD often
resolves by *replicating* heads — measured 16× attention-FLOP inflation on
the 256-chip dry-run (EXPERIMENTS.md §Perf).  The repeat is free per device
(local ``H`` shard sees exactly its own KV slice or a broadcast).

Decode attends a pre-filled cache; with the cache sequence axis sharded
(`model` and/or `data`), the softmax reductions become GSPMD collectives —
the flash-decoding partial-softmax combine falls out of XLA automatically.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import rope as rope_lib
from repro.models.layers import Builder, rms_norm, softcap

NEG_INF = -1e30


def attn_init(b: Builder, cfg) -> dict:
    d, hd, H, KV = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": b.param((d, H * hd), ("embed", "heads")),
        "wk": b.param((d, KV * hd), ("embed", "kv_heads")),
        "wv": b.param((d, KV * hd), ("embed", "kv_heads")),
        "wo": b.param((H * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = b.param((H * hd,), ("heads",), init="zeros")
        p["bk"] = b.param((KV * hd,), ("kv_heads",), init="zeros")
        p["bv"] = b.param((KV * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = b.param((hd,), (None,), init="zeros")
        p["k_norm"] = b.param((hd,), (None,), init="zeros")
    return p


def _project(p, cfg, x):
    B, S, _ = x.shape
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _repeat_kv(k: jax.Array, H: int) -> jax.Array:
    """(B,T,KV,hd) -> (B,T,H,hd): replicate each KV head over its group."""
    KV = k.shape[2]
    if KV == H:
        return k
    return jnp.repeat(k, H // KV, axis=2)


def _direct_attn(q, k, v, *, causal_offset: int, window: int, cap: float,
                 kv_valid: Optional[jax.Array] = None):
    """Direct path. q (B,Sq,H,hd); k/v (B,T,H,hd) (already KV-repeated).

    Query position i (global ``i + causal_offset``) may attend key position
    t iff ``t <= i + causal_offset`` and (window) ``t > i + offset - window``.
    ``kv_valid`` (B,T) optionally masks cache slots (decode).
    """
    B, Sq, H, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum("bshd,bthd->bhst", q, k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if cap:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(Sq)[:, None] + causal_offset
    tpos = jnp.arange(T)[None, :]
    mask = tpos <= qpos                                  # (Sq, T)
    if window:
        mask &= tpos > qpos - window
    if kv_valid is not None:
        mask = mask[None, None] & kv_valid[:, None, None, :]
    else:
        mask = mask[None, None]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), v)
    return o


def _decode_attn_grouped(q, k, v, kv_valid, cap: float,
                         chunk: int = 8192):
    """Single-token decode against a (possibly seq-sharded) cache.
    q (B,1,H,hd); k/v (B,T,KV,hd); kv_valid (B,T).

    Long caches are processed with an online-softmax scan over cache chunks
    (flash-decoding): the f32 score buffer is (B,KV,G,1,chunk), not
    (...,T) — unchunked, decode_32k on 80-95-layer archs peaked >20 GiB/dev.
    """
    B, S, Hq, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = Hq // KV

    def attend(qg, kb, vb, validb):
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb,
                       preferred_element_type=jnp.float32) / np.sqrt(hd)
        if cap:
            s = cap * jnp.tanh(s / cap)
        s = jnp.where(validb[:, None, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgst,btkd->bskgd", w.astype(vb.dtype), vb)
        return o

    # Chunk over the BATCH dim (aligned with the data sharding — a T-dim
    # chunking would fight the model-sharded cache sequence axis): bounds
    # the per-layer f32 score buffer to (chunk_B, KV, G, 1, T_loc).
    chunk_b = 16
    if B > chunk_b and B % chunk_b == 0 and T * B >= 1 << 22:
        nb = B // chunk_b
        # interleaved layout (row m*nb + c -> chunk c): each chunk holds one
        # row per data shard, so the scan never reshards (cf. microbatch_split)
        def split(x):
            return x.reshape(chunk_b, nb, *x.shape[1:]).swapaxes(0, 1)
        qs, ks, vs = split(q), split(k), split(v)
        valids = split(kv_valid)

        def b_step(_, blk):
            qb, kb, vb, vldb = blk
            qg = qb.reshape(chunk_b, S, KV, G, hd)
            return None, attend(qg, kb, vb, vldb)

        _, outs = jax.lax.scan(b_step, None, (qs, ks, vs, valids))
        # invert the interleave: (nb, chunk_b, ...) -> (B, ...)
        o = outs.swapaxes(0, 1).reshape(B, S, KV, G, hd)
        return o.reshape(B, S, Hq, hd)

    o = attend(q.reshape(B, S, KV, G, hd), k, v, kv_valid)
    return o.reshape(B, S, Hq, hd)


def _flash_attn(q, k, v, *, q_chunk: int = 512, kv_chunk: int = 2048,
                cap: float = 0.0):
    """Causal flash-style attention: outer scan over q chunks, inner online
    softmax over kv chunks.  Exact; score tile bounded to (q_chunk, kv_chunk).
    q (B,S,H,hd); k/v (B,S,H,hd) (KV-repeated)."""
    B, S, H, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    nq, nk = S // q_chunk, S // kv_chunk
    qs = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk

        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, kblk, vblk = kj_blk
            s = jnp.einsum("bshd,bthd->bhst", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if cap:
                s = cap * jnp.tanh(s / cap)
            qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
            tpos = kj * kv_chunk + jnp.arange(kv_chunk)[None, :]
            s = jnp.where(tpos <= qpos, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            pmat = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + pmat.sum(-1)
            pv = jnp.einsum("bhst,bthd->bhsd", pmat.astype(vblk.dtype), vblk)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out  # (B,H,q_chunk,hd)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # (nq,B,H,q_chunk,hd) -> (B,S,H,hd)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def _flash_attn_noncausal(q, k, v, *, q_chunk: int = 512,
                          kv_chunk: int = 2048, cap: float = 0.0):
    """Non-causal chunked online-softmax attention (encoder self-attn and
    decoder cross-attn at long lengths — direct scores at 32k×8k are tens
    of GiB).  q (B,Sq,H,hd); k/v (B,Skv,H,hd)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    if Sq % q_chunk or Skv % kv_chunk:
        return _direct_attn(q, k, v, causal_offset=int(1e9), window=0,
                            cap=cap)
    scale = 1.0 / np.sqrt(hd)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    qs = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    def q_step(_, qblk):
        def kv_step(carry, kv_blk):
            m, l, acc = carry
            kblk, vblk = kv_blk
            s = jnp.einsum("bshd,bthd->bhst", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if cap:
                s = cap * jnp.tanh(s / cap)
            m_new = jnp.maximum(m, s.max(-1))
            pmat = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + pmat.sum(-1)
            pv = jnp.einsum("bhst,bthd->bhsd", pmat.astype(vblk.dtype), vblk)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs))
        return None, acc / jnp.maximum(l[..., None], 1e-30)

    _, outs = jax.lax.scan(q_step, None, qs)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def _local_block_attn(q, k, v, *, window: int, cap: float):
    """Exact sliding-window attention: block i attends blocks {i-1, i}.
    q/k/v (B,S,H,hd) (KV-repeated)."""
    B, S, H, hd = q.shape
    assert S % window == 0, (S, window)
    nb = S // window
    scale = 1.0 / np.sqrt(hd)
    qb = q.reshape(B, nb, window, H, hd)
    kb = k.reshape(B, nb, window, H, hd)
    vb = v.reshape(B, nb, window, H, hd)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)            # (B,nb,2w,H,hd)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    s = jnp.einsum("bnshd,bnthd->bnhst", qb, k2,
                   preferred_element_type=jnp.float32) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(window)[:, None] + window          # within 2w frame
    tpos = jnp.arange(2 * window)[None, :]
    mask = (tpos <= qpos) & (tpos > qpos - window)
    first = (jnp.arange(nb) == 0)[:, None, None]         # block 0 has no prev
    mask = mask[None] & ~(first & (tpos[None] < window))  # (nb, w, 2w)
    s = jnp.where(mask[None, :, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnhst,bnthd->bnshd", w.astype(v2.dtype), v2)
    return o.reshape(B, S, H, hd)


def attn_apply(p, cfg, x, cos, sin, *, local: bool = False,
               mode: str = "train", cache: Optional[dict] = None,
               pos: Optional[jax.Array] = None,
               bidirectional: bool = False,
               page_table: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Optional[dict]]:
    """Returns (output, new_cache).  ``pos``: scalar cache fill level
    (decode).  ``mode``: train | prefill | decode | chunk_prefill.

    ``page_table`` switches the cached modes to the slot-paged serving
    layout (repro.serve.kv): ``cache`` holds shared page pools, ``pos``
    is a per-slot fill-level VECTOR, and every read masks ``kv_valid``
    against the slot's own length — the step the continuous-batching
    engine drives (DESIGN.md §9).  ``chunk_prefill`` processes one slot's
    (1, C) prompt chunk at global positions ``pos[0] .. pos[0]+C-1``
    against everything already paged in.
    """
    B, S, _ = x.shape
    H = cfg.n_heads
    window = cfg.window if local else 0
    cap = cfg.attn_softcap
    q, k, v = _project(p, cfg, x)
    q = rope_lib.apply_rope(q, cos, sin)
    k = rope_lib.apply_rope(k, cos, sin)

    if page_table is not None and local and window:
        raise NotImplementedError(
            "paged serving covers full-attention blocks only; the "
            "sliding-window ring-buffer layout has no page-table form yet")

    new_cache = None
    if mode == "decode" and page_table is not None:
        # Slot-paged decode: scatter each slot's new entry to its page,
        # gather its pages to a contiguous view, mask by its own length.
        from repro.serve import kv as kv_lib
        assert cache is not None and S == 1
        P = kv_lib.page_size(cache["k"])
        page, off = kv_lib.token_dest(page_table, pos, P)
        new_cache = {"k": kv_lib.write(cache["k"], page, off, k[:, 0]),
                     "v": kv_lib.write(cache["v"], page, off, v[:, 0])}
        ck = kv_lib.gather(new_cache["k"], page_table, q.dtype)
        cv = kv_lib.gather(new_cache["v"], page_table, q.dtype)
        valid = jnp.arange(ck.shape[1])[None, :] <= pos[:, None]
        o = _decode_attn_grouped(q, ck, cv, valid, cap)
    elif mode == "chunk_prefill":
        from repro.serve import kv as kv_lib
        assert cache is not None and page_table is not None and B == 1, \
            "chunk_prefill is the paged engine's one-slot prompt step"
        P = kv_lib.page_size(cache["k"])
        page, off = kv_lib.chunk_dest(page_table[0], pos[0], S, P)
        new_cache = {"k": kv_lib.write(cache["k"], page, off, k[0]),
                     "v": kv_lib.write(cache["v"], page, off, v[0])}
        ck = kv_lib.gather(new_cache["k"], page_table, q.dtype)
        cv = kv_lib.gather(new_cache["v"], page_table, q.dtype)
        # entries past this chunk's last write are other slots' trash
        valid = jnp.arange(ck.shape[1])[None, :] <= pos[:, None] + (S - 1)
        o = _direct_attn(q, _repeat_kv(ck, H), _repeat_kv(cv, H),
                         causal_offset=pos[0], window=0, cap=cap,
                         kv_valid=valid)
    elif mode == "decode":
        assert cache is not None and S == 1
        size = cache["k"].shape[1]
        slot = pos % size if (local and window) else pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
        valid = jnp.arange(size)[None, :] <= jnp.minimum(pos, size - 1)
        valid = jnp.broadcast_to(valid, (B, size))
        # Grouped einsum, NO KV repeat: materializing the H-head repeat of a
        # sequence-sharded cache costs G× cache memory per layer (measured
        # 25 GiB/dev on deepseek decode_32k).  With the cache seq axis model-
        # sharded and KV replicated, the (KV, G)-split einsum shards cleanly.
        o = _decode_attn_grouped(q, ck, cv, valid, cap)
    elif bidirectional:
        if S > 4096:
            o = _flash_attn_noncausal(q, _repeat_kv(k, H), _repeat_kv(v, H),
                                      cap=cap)
        else:
            o = _direct_attn(q, _repeat_kv(k, H), _repeat_kv(v, H),
                             causal_offset=int(1e9), window=0, cap=cap)
    elif window and S > window and S % window == 0:
        o = _local_block_attn(q, _repeat_kv(k, H), _repeat_kv(v, H),
                              window=window, cap=cap)
    elif window and S > window:
        # non-aligned lengths: direct masked path (O(S²) fallback)
        o = _direct_attn(q, _repeat_kv(k, H), _repeat_kv(v, H),
                         causal_offset=0, window=window, cap=cap)
    elif S > 8192:
        o = _flash_attn(q, _repeat_kv(k, H), _repeat_kv(v, H), cap=cap)
    else:
        o = _direct_attn(q, _repeat_kv(k, H), _repeat_kv(v, H),
                         causal_offset=0, window=window, cap=cap)

    if mode == "prefill":
        if local and window and S > window:
            # ring-buffer handoff: decode writes slot pos % window, so the
            # prompt length must align the ring (slot 0 = oldest).
            assert S % window == 0, (S, window)
            new_cache = {"k": k[:, -window:], "v": v[:, -window:]}
        else:
            new_cache = {"k": k, "v": v}

    out = o.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out, new_cache
