"""Deterministic sample-order spec: a pure ``sample index -> window`` map.

The training stream over a corpus of ``n_windows`` fixed-length windows is
a seeded shuffle, re-shuffled every epoch.  Instead of materializing (and
checkpointing) a permutation array, the shuffle is a **format-preserving
Feistel cipher** over ``[0, n_windows)``: ``window(s)`` for global sample
index ``s`` is a pure function of ``(seed, n_windows, s)`` — O(1) memory,
vectorized over numpy int64 arrays, identical in every process.

That purity is the whole design: any step's batch is recomputable from the
step number alone, so

* SIGTERM + ``--resume`` realigns the stream with **no loader state** in
  the checkpoint,
* worker processes can materialize batch ``i`` in any order and the stream
  is still exactly ``start, start+1, ...``,
* changing worker count / host topology cannot change sample order.

Mechanics: sample ``s`` lives in epoch ``e = s // n`` at offset
``r = s % n``; the window is ``perm_e(r)`` where ``perm_e`` is a 4-round
balanced Feistel network on ``2h`` bits (``2h >= bits(n-1)``), keyed by
``splitmix64(seed, e, round)``, with cycle-walking to stay inside
``[0, n)`` (expected < 2 walks/sample since ``2^2h < 4n``).  Each epoch is
a true permutation of ``range(n)`` (tested), so every window is visited
exactly once per epoch.
"""

from __future__ import annotations

import numpy as np

_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — the per-round hash (vectorized, uint64;
    arithmetic is intentionally mod 2^64)."""
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15)) & _MASK64
        x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
            & _MASK64
        x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
            & _MASK64
        return x ^ (x >> np.uint64(31))


class SampleOrder:
    """Seeded shuffle over ``n_windows`` as a pure index map.

    ``window(s)`` / ``windows(array)`` give the corpus window of global
    sample ``s`` (samples ``[k*B, (k+1)*B)`` form batch ``k`` of size
    ``B``).  No state, no RNG objects — see module docstring.
    """

    ROUNDS = 4

    def __init__(self, n_windows: int, seed: int = 0):
        if n_windows <= 0:
            raise ValueError(f"n_windows must be positive, got {n_windows}")
        self.n_windows = int(n_windows)
        self.seed = int(seed)
        # 2h bits cover n-1; h >= 1 so both Feistel halves are non-trivial
        bits = max(int(n_windows - 1).bit_length(), 2)
        self._h = np.uint64((bits + 1) // 2)
        self._hmask = np.uint64((1 << int(self._h)) - 1)
        self._domain = np.uint64(1) << (np.uint64(2) * self._h)

    def _round_keys(self, epoch: np.ndarray) -> list:
        with np.errstate(over="ignore"):
            base = (np.uint64(self.seed & 0xFFFFFFFFFFFFFFFF)
                    + np.uint64(0xA5A5A5A5) * epoch.astype(np.uint64)) \
                & _MASK64
            return [_splitmix64(
                (base + np.uint64(r) * np.uint64(0xD1B54A32D192ED03))
                & _MASK64) for r in range(self.ROUNDS)]

    def _feistel(self, x: np.ndarray, keys: list) -> np.ndarray:
        left, right = x >> self._h, x & self._hmask
        for k in keys:
            with np.errstate(over="ignore"):
                mixed = _splitmix64((right + k) & _MASK64)
            left, right = right, left ^ (mixed & self._hmask)
        return (left << self._h) | right

    def windows(self, samples: np.ndarray) -> np.ndarray:
        """Vectorized ``sample index -> window index`` (int64 in, int64
        out, all in ``[0, n_windows)``)."""
        samples = np.asarray(samples, np.int64)
        if np.any(samples < 0):
            raise ValueError("sample indices must be non-negative")
        n = np.uint64(self.n_windows)
        epoch = (samples // self.n_windows).astype(np.uint64)
        x = (samples % self.n_windows).astype(np.uint64)
        keys = self._round_keys(epoch)
        x = self._feistel(x, keys)
        # cycle-walk: re-encipher until back inside [0, n) — the walk is a
        # permutation of the 2^2h domain, so distinct inputs stay distinct
        out = np.where(x < n, x, np.uint64(0))
        todo = x >= n
        while np.any(todo):
            x = np.where(todo, self._feistel(x, keys), x)
            done_now = todo & (x < n)
            out = np.where(done_now, x, out)
            todo = todo & ~done_now
        return out.astype(np.int64)

    def window(self, sample: int) -> int:
        return int(self.windows(np.asarray([sample]))[0])

    def epoch_of(self, sample: int) -> int:
        return int(sample) // self.n_windows
