"""Tokenized-corpus shard store: packed token shards + JSON index, read
back through ``np.memmap``.

Layout (one directory per corpus)::

    <dir>/corpus.json        # index: dtype, shard table, splits, hashes
    <dir>/tokenizer.json     # exact tokenizer state (byte or BPE merges)
    <dir>/train_00000.bin …  # packed little-endian uint16/uint32 tokens
    <dir>/eval_00000.bin     # held-out split (tail fraction of the stream)

Design points:

* **Packed + mmapped** — a shard is raw tokens, nothing else; readers map
  it with ``np.memmap`` so a 100-GiB corpus costs no RSS and a random
  window is one page-in.  ``uint16`` when the vocab fits, else ``uint32``.
* **Windows, not documents** — training samples are fixed-length windows
  of ``seq_len + 1`` tokens at stride ``seq_len`` (label of position t is
  token t+1; consecutive windows share one boundary token).  Windows
  never cross shard boundaries, so ``window -> (shard, offset)`` is a
  ``searchsorted`` over cumulative per-shard window counts.
* **Held-out split at build time** — the eval tail is separated when the
  corpus is written, so train/eval windows can never overlap no matter
  what seq_len readers later pick.
* **Content hash** — sha256 over shard bytes + tokenizer config, stored
  in the index; checkpoint manifests record it so a resume onto a
  different corpus fails loudly instead of silently training on the
  wrong data.
* **Picklable readers** — ``TokenStore`` / ``SplitView`` drop their
  memmaps on pickle and re-open them lazily in the child: worker
  processes (``repro.data.workers``) inherit only the path.

No jax imports here (worker-process import graph must stay numpy-only).
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data.tokenizer import dtype_for_vocab, tokenizer_from_json

INDEX_NAME = "corpus.json"
TOKENIZER_NAME = "tokenizer.json"
FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# Writing
# ---------------------------------------------------------------------------

def write_corpus(directory: str, tokens: np.ndarray, tokenizer, *,
                 shard_tokens: int = 1 << 22,
                 eval_fraction: float = 0.05,
                 source_desc: str = "") -> dict:
    """Pack one token stream into shards + index under ``directory``.

    The last ``eval_fraction`` of the stream becomes the eval split
    (document order preserved — the held-out tail, not a random sample,
    so eval text is contiguous prose).  Returns the index dict."""
    os.makedirs(directory, exist_ok=True)
    dt = dtype_for_vocab(tokenizer.vocab_size)
    tokens = np.ascontiguousarray(tokens.astype(dt))
    if tokens.ndim != 1 or tokens.size < 4:
        raise ValueError(f"need a flat token stream, got shape "
                         f"{tokens.shape}")
    n_eval = int(tokens.size * eval_fraction)
    splits = {"train": tokens[:tokens.size - n_eval],
              "eval": tokens[tokens.size - n_eval:]}

    tok_json = tokenizer.to_json()
    with open(os.path.join(directory, TOKENIZER_NAME), "w") as f:
        json.dump(tok_json, f)

    h = hashlib.sha256()
    h.update(json.dumps(tok_json, sort_keys=True).encode())
    index: dict = {"version": FORMAT_VERSION, "dtype": dt.name,
                   "vocab_size": tokenizer.vocab_size,
                   "tokenizer_kind": tokenizer.kind,
                   "source": source_desc, "splits": {}}
    for split, toks in splits.items():
        shards: List[dict] = []
        for i, lo in enumerate(range(0, max(toks.size, 1), shard_tokens)):
            chunk = toks[lo:lo + shard_tokens]
            if chunk.size == 0 and i > 0:
                break
            name = f"{split}_{i:05d}.bin"
            data = chunk.astype(dt.newbyteorder("<")).tobytes()
            with open(os.path.join(directory, name), "wb") as f:
                f.write(data)
            h.update(split.encode())
            h.update(data)
            shards.append({"file": name, "n_tokens": int(chunk.size)})
        index["splits"][split] = {"shards": shards,
                                 "n_tokens": int(toks.size)}
    index["corpus_hash"] = h.hexdigest()
    with open(os.path.join(directory, INDEX_NAME), "w") as f:
        json.dump(index, f, indent=1)
    return index


# ---------------------------------------------------------------------------
# Reading
# ---------------------------------------------------------------------------

class SplitView:
    """Windowed mmap view of one split's shard list.

    ``n_windows(seq_len)`` / ``window(i, seq_len)``: window ``i`` is
    ``seq_len + 1`` tokens starting at ``i * seq_len`` *within its shard*
    (windows never straddle shards; a shard holds
    ``(n_tokens - 1) // seq_len`` of them)."""

    def __init__(self, directory: str, shards: Sequence[dict],
                 dtype: np.dtype):
        self.directory = directory
        self.shards = [dict(s) for s in shards]
        self.dtype = np.dtype(dtype)
        self._maps: Optional[List[np.memmap]] = None
        # seq_len -> (per-shard window counts, exclusive cumsum): built
        # once per seq_len — the window gather is the per-step hot path
        self._tables: Dict[int, tuple] = {}

    @property
    def n_tokens(self) -> int:
        return sum(s["n_tokens"] for s in self.shards)

    def _mapped(self) -> List[np.memmap]:
        if self._maps is None:
            self._maps = [
                np.memmap(os.path.join(self.directory, s["file"]),
                          dtype=self.dtype.newbyteorder("<"), mode="r",
                          shape=(s["n_tokens"],))
                for s in self.shards if s["n_tokens"] > 0]
        return self._maps

    def _window_table(self, seq_len: int) -> tuple:
        if seq_len not in self._tables:
            counts = np.asarray(
                [max(s["n_tokens"] - 1, 0) // seq_len
                 for s in self.shards if s["n_tokens"] > 0], np.int64)
            self._tables[seq_len] = (counts, np.cumsum(counts))
        return self._tables[seq_len]

    def n_windows(self, seq_len: int) -> int:
        return int(self._window_table(seq_len)[0].sum())

    def window(self, i: int, seq_len: int) -> np.ndarray:
        """Window ``i``: ``(seq_len + 1,)`` tokens (inputs + shifted
        labels), copied out of the mmap."""
        return self.windows(np.asarray([i], np.int64), seq_len)[0]

    def windows(self, idx: np.ndarray, seq_len: int) -> np.ndarray:
        """Gather a batch of windows -> ``(len(idx), seq_len + 1)``.
        One vectorized ``searchsorted`` over the cached shard table; the
        mmap reads are the only per-row work."""
        idx = np.asarray(idx, np.int64)
        counts, cum = self._window_table(seq_len)
        total = int(cum[-1]) if len(cum) else 0
        if idx.size and (idx.min() < 0 or idx.max() >= total):
            raise IndexError(f"window index out of range [0, {total})")
        shard_of = np.searchsorted(cum, idx, side="right")
        local = idx - np.where(shard_of > 0, cum[shard_of - 1], 0)
        maps = self._mapped()
        return np.stack([
            np.asarray(maps[s][o:o + seq_len + 1], np.int64)
            for s, o in zip(shard_of, local * seq_len)])

    def tokens(self) -> np.ndarray:
        """The whole split as one array (tests/detokenization only —
        materializes the stream)."""
        maps = self._mapped()
        if not maps:
            return np.zeros((0,), np.int64)
        return np.concatenate([np.asarray(m, np.int64) for m in maps])

    # memmaps don't pickle: drop them, re-open lazily in the child process
    def __getstate__(self):
        d = dict(self.__dict__)
        d["_maps"] = None
        return d


class TokenStore:
    """A built corpus directory: index + tokenizer + split views."""

    def __init__(self, directory: str):
        self.directory = directory
        with open(os.path.join(directory, INDEX_NAME)) as f:
            self.index = json.load(f)
        if self.index.get("version") != FORMAT_VERSION:
            raise ValueError(
                f"corpus {directory} has format version "
                f"{self.index.get('version')}, reader supports "
                f"{FORMAT_VERSION}")
        self.dtype = np.dtype(self.index["dtype"])
        self.vocab_size = int(self.index["vocab_size"])
        self.corpus_hash = self.index["corpus_hash"]
        self._tokenizer = None
        self._views: Dict[str, SplitView] = {}

    @property
    def tokenizer(self):
        if self._tokenizer is None:
            with open(os.path.join(self.directory, TOKENIZER_NAME)) as f:
                self._tokenizer = tokenizer_from_json(json.load(f))
        return self._tokenizer

    def split(self, name: str) -> SplitView:
        if name not in self._views:
            if name not in self.index["splits"]:
                raise KeyError(f"corpus {self.directory} has no split "
                               f"{name!r}; has {list(self.index['splits'])}")
            self._views[name] = SplitView(
                self.directory, self.index["splits"][name]["shards"],
                self.dtype)
        return self._views[name]

    def verify_hash(self) -> bool:
        """Recompute the content hash from bytes on disk (slow; tests and
        the build CLI's --verify use it)."""
        h = hashlib.sha256()
        with open(os.path.join(self.directory, TOKENIZER_NAME)) as f:
            h.update(json.dumps(json.load(f), sort_keys=True).encode())
        for split in self.index["splits"]:
            for s in self.index["splits"][split]["shards"]:
                h.update(split.encode())
                with open(os.path.join(self.directory, s["file"]), "rb") as f:
                    h.update(f.read())
        return h.hexdigest() == self.corpus_hash

    def __getstate__(self):
        d = dict(self.__dict__)
        d["_tokenizer"] = None
        d["_views"] = {}
        return d
