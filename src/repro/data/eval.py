"""Streaming held-out evaluation: perplexity over a fixed eval stream.

``Evaluator`` wraps a jitted per-batch loss and a batch source (usually
the corpus eval split via ``make_source(..., split='eval')`` — sequential
windows, no shuffle) and reduces mean token loss over a FIXED number of
batches, so successive evaluations along a run are comparable points on
one curve.  It only *reads* params — calling it between pipelined train
chunks cannot perturb training numerics, and it composes with donation
(params passed in are the live, about-to-be-donated buffers; the eval
computation holds its own reference until the scalar is fetched).

This module is the one place in ``repro.data`` that imports jax (the
worker-process modules must stay numpy-only); the import is deferred to
call time so building a source in a data worker never pulls XLA in.
"""

from __future__ import annotations

import math
from typing import Callable, List, Optional, Tuple


class Evaluator:
    """Callable ``(params, step) -> {'loss', 'ppl', 'n_batches'}``;
    appends every result to ``history`` as ``(step, loss)``."""

    def __init__(self, loss_fn: Callable, source, n_batches: int = 8,
                 name: str = "eval"):
        """``loss_fn(params, batch) -> scalar mean token loss`` (jitted
        lazily on first call); ``source`` follows the ``batch(i)``
        contract; batches ``0..n_batches-1`` form the eval set."""
        if n_batches < 1:
            raise ValueError(f"n_batches must be >= 1, got {n_batches}")
        self.source = source
        self.n_batches = n_batches
        self.name = name
        self.history: List[Tuple[int, float]] = []
        self._loss_fn = loss_fn
        self._jitted = None

    def __call__(self, params, step: Optional[int] = None) -> dict:
        import jax
        import jax.numpy as jnp
        if self._jitted is None:
            self._jitted = jax.jit(self._loss_fn)
        total = 0.0
        for i in range(self.n_batches):
            batch = {k: jnp.asarray(v)
                     for k, v in self.source.batch(i).items()}
            total += float(self._jitted(params, batch))
        loss = total / self.n_batches
        ppl = math.exp(min(loss, 30.0))   # overflow guard for random init
        if step is not None:
            self.history.append((step, loss))
        return {"loss": loss, "ppl": ppl, "n_batches": self.n_batches}


def make_lm_evaluator(cfg, mod, source, n_batches: int = 8,
                      ctx=None) -> Evaluator:
    """Evaluator over a model module's ``loss_fn`` (``models.lm`` or
    ``models.encdec`` — anything exposing ``loss_fn(cfg, params, batch,
    ctx=...)``).

    When the source is a window-counted corpus split (it exposes
    ``n_windows``/``local_batch``, i.e. ``CorpusLM``), ``n_batches`` is
    CAPPED so the eval set never wraps past the unique held-out windows
    — "perplexity over N batches" must not silently re-score the same
    few windows on a small eval split."""
    n_windows = getattr(source, "n_windows", None)
    rows = getattr(source, "local_batch", None)
    if n_windows and rows:
        unique_batches = max(n_windows // rows, 1)
        if n_batches > unique_batches:
            print(f"[eval] capping eval batches {n_batches} -> "
                  f"{unique_batches}: the held-out split has only "
                  f"{n_windows} windows of {rows} rows")
            n_batches = unique_batches

    def loss(params, batch):
        return mod.loss_fn(cfg, params, batch, ctx=ctx)
    return Evaluator(loss, source, n_batches=n_batches)
