"""Process-worker batch loading: a multiprocessing pool materializing
``source.batch(i)`` into shared memory behind the thread-``Prefetcher``'s
exact ``(index, batch)`` queue contract.

Why processes: the thread Prefetcher decouples *latency* but not *CPU* —
a tokenization-heavy source (pure-python BPE encode) holds the GIL, so
the producer thread and the training host serialize.  Worker processes
each own an interpreter; throughput scales with workers
(``benchmarks/run.py data`` gates process ≥ thread on the heavy source).

Transport: one ``SharedMemory`` segment carved into ``depth`` slots.  A
worker computes a batch, claims a free slot, writes each array into the
slot, and sends ``(index, slot, layout)`` over the (tiny) result queue —
batch payloads never pass through a pickle pipe.  The parent reorders
out-of-order completions in a small dict and emits strictly
``start_step, start_step+1, ...``; because every source's ``batch(i)``
is a pure function of ``i``, the emitted stream is **bitwise identical**
to the thread path for any worker count (tested).

Determinism / resume: nothing here has state worth checkpointing — kill
it, change ``num_workers``, restart at any step; the stream realigns by
construction.

Failure modes mirror the fixed thread Prefetcher: a worker exception is
shipped back (as a pickled exception + formatted traceback) and
re-raised in the consumer's ``__next__``; ``close()`` tears down the
pool (join with timeout, then terminate) and unlinks the segment.

The default start method is ``spawn`` — fork-safety with an initialized
JAX runtime in the parent is not worth betting on — which is why the
whole ``repro.data`` store/order/tokenizer import graph stays
numpy-only: child startup is an interpreter + numpy import, no XLA.
"""

from __future__ import annotations

import multiprocessing as mp
import queue as queue_lib
import threading
import traceback
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

_STOP = None        # task-queue sentinel


def _slot_layout(batch: Dict[str, np.ndarray]) -> Tuple[list, int]:
    """(per-key (name, shape, dtype, offset) table, total bytes) for one
    batch dict — every source yields fixed shapes, so one probe sizes
    the slots for the whole run."""
    layout, off = [], 0
    for k in sorted(batch):
        a = np.ascontiguousarray(batch[k])
        layout.append((k, a.shape, a.dtype.str, off))
        off += a.nbytes
    return layout, off


def _write_slot(buf: memoryview, base: int, batch: Dict[str, np.ndarray],
                layout: list):
    for k, shape, dtype, off in layout:
        a = np.ascontiguousarray(batch[k]).astype(dtype, copy=False)
        dst = np.ndarray(shape, dtype, buffer=buf, offset=base + off)
        dst[...] = a


def _read_slot(buf: memoryview, base: int, layout: list
               ) -> Dict[str, np.ndarray]:
    out = {}
    for k, shape, dtype, off in layout:
        src = np.ndarray(shape, dtype, buffer=buf, offset=base + off)
        out[k] = np.array(src, copy=True)   # copy out before slot reuse
    return out


def _worker_main(source, shm_name: str, slot_bytes: int, tasks, free,
                 results):
    """Worker process body: batch -> claim slot -> write -> report."""
    from multiprocessing import shared_memory
    shm = shared_memory.SharedMemory(name=shm_name)
    try:
        while True:
            i = tasks.get()
            if i is _STOP:
                return
            try:
                batch = source.batch(i)
                layout, nbytes = _slot_layout(batch)
                if nbytes > slot_bytes:
                    raise ValueError(
                        f"batch {i} needs {nbytes}B > slot {slot_bytes}B "
                        f"(source shapes changed mid-stream?)")
                slot = free.get()
                _write_slot(shm.buf, slot * slot_bytes, batch, layout)
                results.put(("ok", i, slot, layout))
            except Exception as e:  # noqa: BLE001 - shipped to consumer
                results.put(("err", i, e, traceback.format_exc()))
                return
    finally:
        shm.close()


class ProcessPrefetcher:
    """Drop-in for :class:`repro.data.pipeline.Prefetcher` backed by
    ``num_workers`` processes + shared-memory slots.  Same protocol:
    iterate for ``(index, batch)`` pairs in exact step order; ``close()``
    (or the context manager) tears the pool down."""

    def __init__(self, source, start_step: int = 0, depth: int = 4,
                 num_workers: int = 2, mp_method: str = "spawn"):
        from multiprocessing import shared_memory
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        self.source = source
        self._next_emit = start_step
        depth = max(depth, num_workers + 1)
        ctx = mp.get_context(mp_method)
        # one probe batch sizes the slots (recomputed by a worker — the
        # probe is discarded so the emitted stream has a single producer)
        layout, nbytes = _slot_layout(source.batch(start_step))
        self._slot_bytes = max(nbytes, 1)
        self._shm = shared_memory.SharedMemory(
            create=True, size=self._slot_bytes * depth)
        # BOUNDED task queue: the queue's own maxsize is the feeder's
        # backpressure (mp.Queue.qsize() is unimplemented on macOS, so a
        # qsize-based high-water mark is not portable)
        self._tasks = ctx.Queue(maxsize=depth + num_workers)
        self._free = ctx.Queue()
        for s in range(depth):
            self._free.put(s)
        self._results = ctx.Queue()
        self._procs: List = [
            ctx.Process(target=_worker_main,
                        args=(source, self._shm.name, self._slot_bytes,
                              self._tasks, self._free, self._results),
                        daemon=True)
            for _ in range(num_workers)]
        for p in self._procs:
            p.start()
        # feeder thread keeps ~depth tasks in flight (bounded by the task
        # queue's maxsize; workers additionally block on the free-slot
        # ring, so host memory never grows with the step count)
        self._stop = threading.Event()
        self._feeder = threading.Thread(target=self._feed,
                                        args=(start_step,), daemon=True)
        self._feeder.start()
        self._pending: Dict[int, Tuple[int, list]] = {}
        self._exc: Optional[BaseException] = None
        self._exc_at: Optional[int] = None   # first failed batch index
        self._closed = False

    def _feed(self, start: int):
        i = start
        while not self._stop.is_set():
            try:
                self._tasks.put(i, timeout=0.1)
                i += 1
            except queue_lib.Full:   # bounded queue = the backpressure
                continue

    # -- consumer ----------------------------------------------------------
    def __iter__(self) -> Iterator:
        return self

    def _absorb(self, msg):
        """Copy a completion out of its slot and free the slot
        IMMEDIATELY — holding slots for out-of-order pendings could
        exhaust the ring while the wanted batch's worker blocks on
        ``free.get()`` (classic reorder deadlock).  Pending host copies
        are bounded by the feeder high-water mark."""
        if msg[0] == "err":
            _, i, exc, tb = msg
            if self._exc_at is None or i < self._exc_at:
                exc.args = (f"{exc.args[0] if exc.args else exc!r} "
                            f"[in data worker, batch {i}]\n{tb}",) \
                    + tuple(exc.args[1:])
                self._exc, self._exc_at = exc, i
            return
        _, i, slot, layout = msg
        batch = _read_slot(self._shm.buf, slot * self._slot_bytes, layout)
        self._free.put(slot)
        if i >= self._next_emit:
            self._pending[i] = batch

    def __next__(self):
        want = self._next_emit
        while want not in self._pending:
            try:                      # drain everything already completed
                self._absorb(self._results.get_nowait())
                continue
            except queue_lib.Empty:
                pass
            if self._exc is not None:
                # the stream is valid strictly below the first failed
                # index (workers take tasks in order, so batches < exc_at
                # belong to workers that finished or are still alive) —
                # raise only once the consumer reaches it, or when the
                # whole pool is dead and the batch can never arrive
                if (self._exc_at is None or want >= self._exc_at
                        or not any(p.is_alive() for p in self._procs)):
                    raise self._exc
            try:
                self._absorb(self._results.get(timeout=0.5))
            except queue_lib.Empty:
                if not any(p.is_alive() for p in self._procs):
                    self._exc = RuntimeError(
                        "all data workers exited without producing "
                        f"batch {want}")
        batch = self._pending.pop(want)
        self._next_emit = want + 1
        return want, batch

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "ProcessPrefetcher":
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self, timeout: float = 5.0):
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._feeder.join(timeout)
        for _ in self._procs:
            try:
                self._tasks.put_nowait(_STOP)
            except queue_lib.Full:
                break
        deadline = timeout
        for p in self._procs:
            p.join(timeout=max(deadline / max(len(self._procs), 1), 0.2))
            if p.is_alive():
                p.terminate()
                p.join(timeout=1.0)
        for q in (self._tasks, self._free, self._results):
            q.cancel_join_thread()
            q.close()
        try:
            self._shm.close()
            self._shm.unlink()
        except FileNotFoundError:
            pass
