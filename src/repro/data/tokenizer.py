"""Tokenizers for the corpus store: byte-level and a trainable byte-BPE.

Both are numpy/pure-python only — this module is imported inside data
worker processes (``repro.data.workers``), and keeping ``jax`` out of the
import graph keeps spawn-start cheap and fork-safe.

Contract shared by both:

* ``encode(text) -> np.ndarray`` of token ids (dtype fits ``vocab_size``),
* ``decode(ids) -> str`` with ``decode(encode(t)) == t`` for any UTF-8
  text (byte-level base alphabet: nothing is out-of-vocabulary),
* ``to_json`` / ``from_json`` round-trip the trained state, so the
  corpus index can pin the exact tokenizer it was built with
  (``config_hash`` feeds the corpus hash).

The BPE is the standard byte-level scheme: pre-tokenize into
whitespace-glued words (a space belongs to the word it precedes, so
merges never straddle word boundaries and decoding is pure
concatenation), then greedily apply learned merges by rank.  Training
recounts pairs per merge over the unique-word histogram — O(merges ×
unique words), plenty for fixture-scale corpora, and deterministic:
ties break on the lexicographically smallest pair.
"""

from __future__ import annotations

import hashlib
import json
import re
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

# a word = optional leading whitespace glued to the following non-space run,
# or a trailing whitespace-only run; concatenating words restores the text.
_WORD_RE = re.compile(r"\s*\S+|\s+$")


def dtype_for_vocab(vocab_size: int) -> np.dtype:
    """Smallest packed dtype the store uses for this alphabet."""
    return np.dtype(np.uint16 if vocab_size <= (1 << 16) else np.uint32)


class ByteTokenizer:
    """Identity byte-level tokenizer: one token per UTF-8 byte."""

    kind = "byte"

    def __init__(self):
        self.vocab_size = 256

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), np.uint8) \
            .astype(dtype_for_vocab(self.vocab_size))

    def decode(self, ids: Sequence[int]) -> str:
        return bytes(np.asarray(ids, np.uint8)).decode("utf-8",
                                                       errors="replace")

    def to_json(self) -> dict:
        return {"kind": self.kind, "vocab_size": self.vocab_size}

    @classmethod
    def from_json(cls, obj: dict) -> "ByteTokenizer":
        tok = cls()
        assert obj["kind"] == cls.kind
        return tok

    def config_hash(self) -> str:
        return hashlib.sha256(
            json.dumps(self.to_json(), sort_keys=True).encode()).hexdigest()


class BPETokenizer:
    """Byte-level BPE: 256 byte tokens + trained merges.

    ``merges`` is an ordered list of ``(left_id, right_id)`` pairs; merge
    ``i`` defines token ``256 + i``.  Encoding applies merges greedily by
    rank within each word (lowest-rank pair first — the classic BPE encode
    loop), which is exactly the GIL-heavy per-batch work the process-worker
    path exists for.
    """

    kind = "bpe"

    def __init__(self, merges: Sequence[Tuple[int, int]] = ()):
        self.merges: List[Tuple[int, int]] = [tuple(m) for m in merges]
        self.vocab_size = 256 + len(self.merges)
        self._ranks: Dict[Tuple[int, int], int] = {
            m: i for i, m in enumerate(self.merges)}
        # token id -> raw bytes, built bottom-up (merge i only references
        # ids < 256 + i)
        self._bytes: List[bytes] = [bytes([b]) for b in range(256)]
        for a, b in self.merges:
            self._bytes.append(self._bytes[a] + self._bytes[b])

    # -- train -------------------------------------------------------------
    @classmethod
    def train(cls, texts: Iterable[str], vocab_size: int) -> "BPETokenizer":
        """Learn ``vocab_size - 256`` merges from ``texts``.

        Deterministic: pair counts are exact over the unique-word
        histogram and ties break on the smallest pair tuple."""
        if vocab_size < 256:
            raise ValueError(f"vocab_size {vocab_size} < 256 byte alphabet")
        words: Dict[Tuple[int, ...], int] = {}
        for text in texts:
            for m in _WORD_RE.finditer(text):
                w = tuple(m.group().encode("utf-8"))
                words[w] = words.get(w, 0) + 1
        merges: List[Tuple[int, int]] = []
        for new_id in range(256, vocab_size):
            counts: Dict[Tuple[int, int], int] = {}
            for w, c in words.items():
                for pair in zip(w, w[1:]):
                    counts[pair] = counts.get(pair, 0) + c
            if not counts:
                break
            best = min(counts.items(), key=lambda kv: (-kv[1], kv[0]))[0]
            if counts[best] < 2:
                break  # nothing left worth merging
            merges.append(best)
            words = {cls._merge_word(w, best, new_id): c
                     for w, c in words.items()}
        return cls(merges)

    @staticmethod
    def _merge_word(w: Tuple[int, ...], pair: Tuple[int, int],
                    new_id: int) -> Tuple[int, ...]:
        out: List[int] = []
        i = 0
        while i < len(w):
            if i + 1 < len(w) and (w[i], w[i + 1]) == pair:
                out.append(new_id)
                i += 2
            else:
                out.append(w[i])
                i += 1
        return tuple(out)

    # -- encode / decode ---------------------------------------------------
    def _encode_word(self, w: List[int]) -> List[int]:
        while len(w) > 1:
            best_rank, best_i = None, -1
            for i in range(len(w) - 1):
                r = self._ranks.get((w[i], w[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_i = r, i
            if best_rank is None:
                break
            w[best_i:best_i + 2] = [256 + best_rank]
        return w

    def encode(self, text: str) -> np.ndarray:
        ids: List[int] = []
        for m in _WORD_RE.finditer(text):
            ids.extend(self._encode_word(list(m.group().encode("utf-8"))))
        return np.asarray(ids, dtype_for_vocab(self.vocab_size))

    def decode(self, ids: Sequence[int]) -> str:
        return b"".join(self._bytes[int(i)] for i in np.asarray(ids).ravel()) \
            .decode("utf-8", errors="replace")

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict:
        return {"kind": self.kind, "vocab_size": self.vocab_size,
                "merges": [list(m) for m in self.merges]}

    @classmethod
    def from_json(cls, obj: dict) -> "BPETokenizer":
        assert obj["kind"] == cls.kind
        return cls([tuple(m) for m in obj["merges"]])

    def config_hash(self) -> str:
        return hashlib.sha256(
            json.dumps(self.to_json(), sort_keys=True).encode()).hexdigest()


def make_tokenizer(kind: str, texts: Iterable[str] = (),
                   vocab_size: int = 512):
    if kind == "byte":
        return ByteTokenizer()
    if kind == "bpe":
        return BPETokenizer.train(texts, vocab_size)
    raise ValueError(f"unknown tokenizer kind {kind!r}; choices: byte|bpe")


def tokenizer_from_json(obj: dict):
    cls = {ByteTokenizer.kind: ByteTokenizer, BPETokenizer.kind: BPETokenizer}
    return cls[obj["kind"]].from_json(obj)
