"""LM data pipeline: deterministic synthetic corpus + byte-level text, with
background prefetch and exact resumability.

The container is offline (no C4); the pipeline provides
* ``synthetic``: a mixture of repeated n-gram "grammars" per document —
  enough structure that models separate by optimizer quality (used by the
  Table II/IV proxies), and
* ``bytes``: byte-level tokens from any local file glob.

Determinism/resume: batch ``i`` depends only on ``(seed, i)`` — restoring a
checkpoint at step ``s`` resumes the stream exactly (fault-tolerance test
covers this).  Prefetch runs in a daemon thread with a bounded queue
(straggler decoupling on the input side).
"""

from __future__ import annotations

import glob as globlib
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    """Documents = noisy walks over a per-document Markov chain."""

    def __init__(self, vocab: int, seq_len: int, batch_size: int,
                 seed: int = 0, n_chains: int = 64, order_vocab: int = 512):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        base = np.random.RandomState(seed)
        self.n_chains = n_chains
        self._next = base.randint(
            0, min(vocab, order_vocab),
            size=(n_chains, min(vocab, order_vocab), 4)).astype(np.int32)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        B, S = self.batch_size, self.seq_len
        chains = rng.randint(0, self.n_chains, size=B)
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.randint(0, self._next.shape[1], size=B)
        noise = rng.random((B, S)) < 0.05
        branch = rng.randint(0, 4, size=(B, S))
        rand_tok = rng.randint(0, self._next.shape[1], size=(B, S))
        for t in range(S):
            nxt = self._next[chains, toks[:, t], branch[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ByteLM:
    """Byte-level tokens from local files (self-hosting corpus: this repo)."""

    def __init__(self, pattern: str, seq_len: int, batch_size: int,
                 seed: int = 0, vocab: int = 256):
        paths = sorted(globlib.glob(pattern, recursive=True))
        if not paths:
            raise FileNotFoundError(f"no files match {pattern!r}")
        blobs = []
        for p in paths:
            try:
                blobs.append(np.frombuffer(open(p, "rb").read(), np.uint8))
            except OSError:
                continue
        self.data = np.concatenate(blobs).astype(np.int32) % vocab
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        B, S = self.batch_size, self.seq_len
        starts = rng.randint(0, len(self.data) - S - 1, size=B)
        toks = np.stack([self.data[s:s + S + 1] for s in starts])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class WithEncoderFrames:
    """Encoder-decoder adapter: rides deterministic frame embeddings
    ``(B, n_frames, d_model)`` along each LM batch (the audio-frontend stub
    for seamless-style encdec training — previously a ``source.batch``
    monkey-patch in launch/train.py).

    Determinism matches the base source's contract: ``batch(i)`` depends
    only on ``i`` (frames are seeded by the batch index alone, preserving
    the pre-adapter stream for resume alignment)."""

    def __init__(self, source, n_frames: int, d_model: int):
        self.source = source
        self.n_frames = n_frames
        self.d_model = d_model
        self.batch_size = source.batch_size

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        b = dict(self.source.batch(index))
        rng = np.random.RandomState(index)
        b["enc_embeds"] = rng.randn(
            self.batch_size, self.n_frames, self.d_model).astype(np.float32)
        return b


def stack_batches(batches) -> Dict[str, np.ndarray]:
    """Stack a list of ``batch(i)`` dicts along a new leading axis —
    the xs of the train loop's scan-over-steps superstep."""
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


class Prefetcher:
    """Bounded-queue background prefetch over ``source.batch(i)``,
    resumable from any step.  Usable as a context manager; batch order is
    exactly ``start_step, start_step+1, ...`` (the consumer may assert the
    yielded index for stream-alignment checks)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        i = self._step
        pending = None
        while not self._stop.is_set():
            if pending is None:
                pending = (i, self.source.batch(i))  # computed exactly once
            try:
                self._q.put(pending, timeout=0.5)
                pending = None
                i += 1
            except queue.Full:   # retry the put only — never the batch gen
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        i, b = self._q.get()
        return i, b

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        self._stop.set()


def make_source(kind: str, vocab: int, seq_len: int, batch_size: int,
                seed: int = 0, pattern: Optional[str] = None,
                enc_frames: int = 0, enc_dim: int = 0):
    """``enc_frames``/``enc_dim`` > 0 wrap the source in
    :class:`WithEncoderFrames` (encoder-decoder training batches)."""
    if kind == "synthetic":
        src = SyntheticLM(vocab, seq_len, batch_size, seed)
    elif kind == "bytes":
        src = ByteLM(pattern or "src/**/*.py", seq_len, batch_size, seed,
                     vocab=min(vocab, 256))
    else:
        raise ValueError(f"unknown data source {kind!r}")
    if enc_frames and enc_dim:
        src = WithEncoderFrames(src, enc_frames, enc_dim)
    return src
