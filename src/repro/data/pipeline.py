"""LM data pipeline: batch sources + background prefetch with exact
resumability.

Sources (all share the contract *batch ``i`` depends only on
``(config, i)``* — restoring a checkpoint at step ``s`` resumes the
stream exactly, with no loader state anywhere):

* ``synthetic``: a mixture of repeated n-gram "grammars" per document —
  enough structure that models separate by optimizer quality (used by the
  Table II/IV proxies),
* ``bytes``: byte-level tokens from any local file glob,
* ``corpus``: fixed-length windows over a pre-tokenized mmap shard store
  (``repro.data.store``) visited in the pure seeded-shuffle order of
  ``repro.data.order`` — the real pre-training path, with per-host DP
  slicing (``dp_rank``/``dp_size``),
* :class:`TokenizingTextLM`: on-the-fly BPE over raw text — the
  GIL-heavy source the process-worker path
  (``repro.data.workers.ProcessPrefetcher``) exists for.

Prefetch runs in a daemon thread with a bounded queue (straggler
decoupling on the input side); source exceptions are captured and
re-raised in the consumer (``__next__``), never swallowed in the worker
thread.
"""

from __future__ import annotations

import glob as globlib
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

_ERROR = object()   # Prefetcher queue sentinel: (index slot) for failures


class SyntheticLM:
    """Documents = noisy walks over a per-document Markov chain."""

    def __init__(self, vocab: int, seq_len: int, batch_size: int,
                 seed: int = 0, n_chains: int = 64, order_vocab: int = 512):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        base = np.random.RandomState(seed)
        self.n_chains = n_chains
        self._next = base.randint(
            0, min(vocab, order_vocab),
            size=(n_chains, min(vocab, order_vocab), 4)).astype(np.int32)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        B, S = self.batch_size, self.seq_len
        chains = rng.randint(0, self.n_chains, size=B)
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.randint(0, self._next.shape[1], size=B)
        noise = rng.random((B, S)) < 0.05
        branch = rng.randint(0, 4, size=(B, S))
        rand_tok = rng.randint(0, self._next.shape[1], size=(B, S))
        for t in range(S):
            nxt = self._next[chains, toks[:, t], branch[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ByteLM:
    """Byte-level tokens from local files (self-hosting corpus: this repo)."""

    def __init__(self, pattern: str, seq_len: int, batch_size: int,
                 seed: int = 0, vocab: int = 256):
        paths = sorted(globlib.glob(pattern, recursive=True))
        if not paths:
            raise FileNotFoundError(f"no files match {pattern!r}")
        blobs = []
        for p in paths:
            try:
                blobs.append(np.frombuffer(open(p, "rb").read(), np.uint8))
            except OSError:
                continue
        self.data = np.concatenate(blobs).astype(np.int32) % vocab
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        B, S = self.batch_size, self.seq_len
        starts = rng.randint(0, len(self.data) - S - 1, size=B)
        toks = np.stack([self.data[s:s + S + 1] for s in starts])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class CorpusLM:
    """Fixed-length windows over a pre-tokenized mmap corpus
    (``repro.data.store``), visited in the pure seeded-shuffle order of
    ``repro.data.order.SampleOrder``.

    ``batch_size`` is the GLOBAL batch; ``dp_rank``/``dp_size`` slice it
    per host (rank ``r`` produces rows ``[r·B/H, (r+1)·B/H)`` of every
    batch — concatenating the slices over ranks reproduces the full
    batch bitwise, so per-host loading composes with the sharded train
    path's ``batch_shardings``).  ``split='eval'`` defaults to the
    sequential (unshuffled) order the eval harness streams in.

    Picklable (the mmap re-opens lazily in the child) — this is the
    source the process workers are built around."""

    def __init__(self, corpus_dir: str, seq_len: int, batch_size: int,
                 seed: int = 0, split: str = "train",
                 shuffle: Optional[bool] = None,
                 dp_rank: int = 0, dp_size: int = 1):
        from repro.data.order import SampleOrder
        from repro.data.store import TokenStore
        if batch_size % dp_size:
            raise ValueError(f"global batch {batch_size} not divisible by "
                             f"dp_size {dp_size}")
        if not 0 <= dp_rank < dp_size:
            raise ValueError(f"dp_rank {dp_rank} outside [0, {dp_size})")
        self.store = TokenStore(corpus_dir)
        self.view = self.store.split(split)
        self.seq_len = seq_len
        self.batch_size = batch_size          # global
        self.local_batch = batch_size // dp_size
        self.dp_rank, self.dp_size = dp_rank, dp_size
        self.seed = seed
        self.split = split
        self.vocab = self.store.vocab_size
        self.n_windows = self.view.n_windows(seq_len)
        if self.n_windows < 1:
            raise ValueError(
                f"corpus split {split!r} has no seq_len={seq_len} windows "
                f"({self.view.n_tokens} tokens)")
        self.shuffle = (split == "train") if shuffle is None else shuffle
        self.order = SampleOrder(self.n_windows, seed) if self.shuffle \
            else None

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        base = index * self.batch_size + self.dp_rank * self.local_batch
        samples = np.arange(base, base + self.local_batch, dtype=np.int64)
        wins = self.order.windows(samples) if self.order is not None \
            else samples % self.n_windows
        toks = self.view.windows(wins, self.seq_len).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenizingTextLM:
    """On-the-fly BPE over raw text: every ``batch(i)`` ENCODES text —
    deliberately GIL-bound pure-python work.  This is the
    tokenization-heavy source the process-worker benchmark gates on; the
    pre-tokenized :class:`CorpusLM` is the fast path for training."""

    def __init__(self, text: str, tokenizer, seq_len: int, batch_size: int,
                 seed: int = 0, chars_per_token: int = 6):
        self.text = text
        self.tokenizer = tokenizer
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        self.span = (seq_len + 1) * chars_per_token
        if len(text) <= self.span:
            raise ValueError(f"text of {len(text)} chars too short for "
                             f"span {self.span}")

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        starts = rng.randint(0, len(self.text) - self.span,
                             size=self.batch_size)
        S = self.seq_len
        toks = np.zeros((self.batch_size, S + 1), np.int32)
        for r, s in enumerate(starts):
            ids = self.tokenizer.encode(self.text[s:s + self.span])
            ids = ids[:S + 1]
            toks[r, :len(ids)] = ids
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class WithEncoderFrames:
    """Encoder-decoder adapter: rides deterministic frame embeddings
    ``(B, n_frames, d_model)`` along each LM batch (the audio-frontend stub
    for seamless-style encdec training — previously a ``source.batch``
    monkey-patch in launch/train.py).

    Determinism matches the base source's contract: ``batch(i)`` depends
    only on ``i`` (frames are seeded by the batch index alone, preserving
    the pre-adapter stream for resume alignment)."""

    def __init__(self, source, n_frames: int, d_model: int):
        self.source = source
        self.n_frames = n_frames
        self.d_model = d_model
        self.batch_size = source.batch_size

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        b = dict(self.source.batch(index))
        rng = np.random.RandomState(index)
        b["enc_embeds"] = rng.randn(
            self.batch_size, self.n_frames, self.d_model).astype(np.float32)
        return b


def stack_batches(batches) -> Dict[str, np.ndarray]:
    """Stack a list of ``batch(i)`` dicts along a new leading axis —
    the xs of the train loop's scan-over-steps superstep."""
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


class Prefetcher:
    """Bounded-queue background prefetch over ``source.batch(i)``,
    resumable from any step.  Usable as a context manager; batch order is
    exactly ``start_step, start_step+1, ...`` (the consumer may assert the
    yielded index for stream-alignment checks).

    A ``source.batch(i)`` exception does NOT kill the worker silently:
    it is captured, enqueued behind any already-produced batches, and
    re-raised in the consumer's ``__next__`` (repeatedly, if called
    again).  ``close()`` joins the thread (bounded wait), not just sets
    the stop event."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._step = start_step
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        i = self._step
        pending = None
        while not self._stop.is_set():
            if pending is None:
                try:
                    pending = (i, self.source.batch(i))  # computed once
                except BaseException as e:  # noqa: BLE001 - re-raised in
                    self._exc = e           # the consumer, not swallowed
                    pending = (_ERROR, e)
            try:
                self._q.put(pending, timeout=0.5)
                if pending[0] is _ERROR:
                    return
                pending = None
                i += 1
            except queue.Full:   # retry the put only — never the batch gen
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            if self._exc is not None:
                # producer is dead (or dying): drain what it finished,
                # then (re-)raise its error instead of blocking forever
                try:
                    i, b = self._q.get_nowait()
                except queue.Empty:
                    raise self._exc
            else:
                i, b = self._q.get()
            if i is _ERROR:
                raise b
            return i, b

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self, timeout: float = 5.0):
        """Stop and JOIN the producer.  The queue is drained while
        joining so a producer blocked in ``put`` returns immediately
        instead of sitting out its 0.5 s timeout — ``close()`` runs once
        per ``TrainLoop.run``, and that stall was measurable in the step
        benchmark's short runs."""
        import time as _time
        self._stop.set()
        deadline = _time.monotonic() + timeout
        while self._thread.is_alive() and _time.monotonic() < deadline:
            try:
                self._q.get_nowait()   # unblock a put()-blocked producer
            except queue.Empty:
                pass
            self._thread.join(0.05)


def make_source(kind: str, vocab: int, seq_len: int, batch_size: int,
                seed: int = 0, pattern: Optional[str] = None,
                enc_frames: int = 0, enc_dim: int = 0,
                corpus_dir: Optional[str] = None, split: str = "train",
                dp_rank: int = 0, dp_size: int = 1):
    """``enc_frames``/``enc_dim`` > 0 wrap the source in
    :class:`WithEncoderFrames` (encoder-decoder training batches).

    ``split='eval'`` builds the held-out stream: the corpus eval split
    (sequential windows) for ``corpus``, a disjoint seed stream for the
    synthetic/bytes proxies (``vocab`` must cover the model's table; the
    corpus source uses the store's own vocab and merely checks it
    fits)."""
    eval_split = split == "eval"
    if eval_split and kind != "corpus":
        seed = seed ^ 0x5EED_E7A1  # disjoint deterministic stream
    if kind == "synthetic":
        src = SyntheticLM(vocab, seq_len, batch_size, seed)
    elif kind == "bytes":
        src = ByteLM(pattern or "src/**/*.py", seq_len, batch_size, seed,
                     vocab=min(vocab, 256))
    elif kind == "corpus":
        if not corpus_dir:
            raise ValueError("data kind 'corpus' needs corpus_dir "
                             "(--corpus-dir: a directory built by "
                             "repro.data.build_corpus)")
        src = CorpusLM(corpus_dir, seq_len, batch_size, seed=seed,
                       split=split, dp_rank=dp_rank, dp_size=dp_size)
        if src.vocab > vocab:
            raise ValueError(f"corpus vocab {src.vocab} exceeds model "
                             f"vocab {vocab}")
    else:
        raise ValueError(f"unknown data source {kind!r}")
    if enc_frames and enc_dim:
        src = WithEncoderFrames(src, enc_frames, enc_dim)
    return src
