"""Corpus builder CLI: raw text -> packed token shards + index.

    PYTHONPATH=src python -m repro.data.build_corpus \
        --input 'tests/fixtures/corpus/*.txt' --out /tmp/corpus \
        --tokenizer bpe --vocab 512 [--eval-fraction 0.05] [--verify]

Reads every file matching the glob (sorted, so the stream is
deterministic), joins documents with a blank line, trains the tokenizer
(``bpe``) or uses the fixed byte alphabet (``byte``), tokenizes, and
writes the shard store (see ``repro.data.store``).  ``--verify`` re-opens
the result, checks the content hash and a decode round-trip, and prints
the stats the smoke gate greps for.
"""

from __future__ import annotations

import argparse
import glob as globlib
import sys

import numpy as np

from repro.data import store as store_lib
from repro.data.tokenizer import make_tokenizer

DOC_SEP = "\n\n"


def read_documents(pattern: str) -> list:
    paths = sorted(globlib.glob(pattern, recursive=True))
    if not paths:
        raise FileNotFoundError(f"no files match {pattern!r}")
    docs = []
    for p in paths:
        with open(p, encoding="utf-8") as f:
            docs.append(f.read())
    return docs


def build(pattern: str, out_dir: str, *, tokenizer_kind: str = "bpe",
          vocab_size: int = 512, eval_fraction: float = 0.05,
          shard_tokens: int = 1 << 22) -> dict:
    """Library entry point (the CLI and tests/benchmarks call this)."""
    docs = read_documents(pattern)
    text = DOC_SEP.join(docs)
    tok = make_tokenizer(tokenizer_kind, texts=docs, vocab_size=vocab_size)
    tokens = tok.encode(text)
    return store_lib.write_corpus(out_dir, np.asarray(tokens), tok,
                                  shard_tokens=shard_tokens,
                                  eval_fraction=eval_fraction,
                                  source_desc=pattern)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--input", required=True,
                    help="glob of raw UTF-8 text files (sorted -> "
                         "deterministic stream)")
    ap.add_argument("--out", required=True, help="corpus directory to write")
    ap.add_argument("--tokenizer", default="bpe", choices=["byte", "bpe"])
    ap.add_argument("--vocab", type=int, default=512,
                    help="BPE target vocab (>= 256; ignored for byte)")
    ap.add_argument("--eval-fraction", type=float, default=0.05,
                    help="held-out tail fraction of the token stream")
    ap.add_argument("--shard-tokens", type=int, default=1 << 22)
    ap.add_argument("--verify", action="store_true",
                    help="re-open, check hash + decode round-trip")
    args = ap.parse_args(argv)

    index = build(args.input, args.out, tokenizer_kind=args.tokenizer,
                  vocab_size=args.vocab, eval_fraction=args.eval_fraction,
                  shard_tokens=args.shard_tokens)
    tr = index["splits"]["train"]["n_tokens"]
    ev = index["splits"]["eval"]["n_tokens"]
    print(f"corpus: {args.out} vocab={index['vocab_size']} "
          f"dtype={index['dtype']} train_tokens={tr} eval_tokens={ev} "
          f"hash={index['corpus_hash'][:12]}")
    if args.verify:
        st = store_lib.TokenStore(args.out)
        ok = st.verify_hash()
        toks = np.concatenate([st.split("train").tokens(),
                               st.split("eval").tokens()])
        text = DOC_SEP.join(read_documents(args.input))
        roundtrip = st.tokenizer.decode(toks) == text
        print(f"verify: hash={'ok' if ok else 'MISMATCH'} "
              f"roundtrip={'ok' if roundtrip else 'MISMATCH'}")
        if not (ok and roundtrip):
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
