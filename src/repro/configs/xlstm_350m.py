"""xlstm-350m [ssm] — 7:1 mLSTM:sLSTM interleave, no separate FFN (d_ff=0)
[arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab=50304,
    pattern=("mlstm",) * 7 + ("slstm",),
    tie_embeddings=True, sub_quadratic=True,
)

SMOKE = CONFIG.with_(
    n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    vocab=512, remat=False)
