"""The paper's LLaMA pre-training family (Appendix Table VIII) — used by the
examples and the Table II/III/IV/XI/XII benchmark proxies."""
from repro.configs.base import ModelConfig


def _llama(name, n_layers, d_model, n_heads, d_ff, vocab=32000):
    return ModelConfig(
        name=name, family="dense",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_heads, head_dim=d_model // n_heads,
        d_ff=d_ff, vocab=vocab, pattern=("attn",),
        tie_embeddings=True, sub_quadratic=False, remat=False)


def smoke(cfg: ModelConfig) -> ModelConfig:
    """CI-scale variant of a LLaMA family member: 2 layers, d=32, f32.

    Small enough that the *runtime* (dispatch, data fetch, host syncs)
    is a visible fraction of the step — the regime the train-loop
    benchmark and the preempt/resume tests exercise on CPU."""
    return cfg.with_(
        name=f"{cfg.name}-smoke", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=64, dtype="float32",
        remat=False)


LLAMA_60M = _llama("llama-60m", 8, 512, 8, 1376)
LLAMA_130M = _llama("llama-130m", 12, 768, 12, 2048)
LLAMA_350M = _llama("llama-350m", 24, 1024, 16, 2736)
LLAMA_1B = _llama("llama-1b", 32, 2048, 24, 5461)
LLAMA_3B = _llama("llama-3b", 32, 2560, 32, 6848)
