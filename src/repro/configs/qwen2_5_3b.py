"""qwen2.5-3b [dense] — GQA kv=2, QKV bias [hf:Qwen/Qwen2.5 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab=151936,
    pattern=("attn",), qkv_bias=True, rope_theta=1e6,
    tie_embeddings=True, sub_quadratic=False,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, remat=False)
