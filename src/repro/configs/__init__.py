"""Architecture registry: the 10 assigned archs + the paper's LLaMA family.

``get_config(id)`` / ``get_smoke(id)`` accept the assignment's dashed ids.
"""

from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, input_specs,
                                skip_reason)

from repro.configs import (jamba_v0_1_52b, qwen3_moe_30b_a3b, qwen2_moe_a2_7b,
                           gemma3_27b, deepseek_67b, gemma2_9b, qwen2_5_3b,
                           qwen2_vl_72b, xlstm_350m, seamless_m4t_large_v2,
                           llama_paper)

_MODULES = {
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "gemma3-27b": gemma3_27b,
    "deepseek-67b": deepseek_67b,
    "gemma2-9b": gemma2_9b,
    "qwen2.5-3b": qwen2_5_3b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "xlstm-350m": xlstm_350m,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
}

LLAMA = {
    "llama-60m": llama_paper.LLAMA_60M,
    "llama-130m": llama_paper.LLAMA_130M,
    "llama-350m": llama_paper.LLAMA_350M,
    "llama-1b": llama_paper.LLAMA_1B,
    "llama-3b": llama_paper.LLAMA_3B,
}

ARCH_IDS = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name in _MODULES:
        return _MODULES[name].CONFIG
    if name in LLAMA:
        return LLAMA[name]
    raise ValueError(f"unknown arch {name!r}; choices: {ARCH_IDS + list(LLAMA)}")


def get_smoke(name: str) -> ModelConfig:
    if name in _MODULES:
        return _MODULES[name].SMOKE
    if name in LLAMA:
        return llama_paper.smoke(LLAMA[name])
    raise ValueError(
        f"unknown arch {name!r}; choices: {ARCH_IDS + list(LLAMA)}")


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "input_specs",
           "skip_reason", "get_config", "get_smoke", "ARCH_IDS", "LLAMA"]
