"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, QK-norm, all layers MoE
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab=151936,
    pattern=("attn+moe",),
    n_experts=128, top_k=8, d_ff_expert=768,
    qk_norm=True, rope_theta=1e6,
    tie_embeddings=False, sub_quadratic=False,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    vocab=512, n_experts=8, top_k=2, d_ff_expert=64, remat=False,
    capacity_factor=8.0)  # smoke: no capacity drops -> decode == train
