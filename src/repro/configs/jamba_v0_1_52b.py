"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].  Period-8 Jamba block: attention at index 4, MoE on odd
indices (every other layer)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    pattern=("mamba", "mamba+moe", "mamba", "mamba+moe",
             "attn", "mamba+moe", "mamba", "mamba+moe"),
    n_experts=16, top_k=2, d_ff_expert=14336,
    tie_embeddings=False, sub_quadratic=True,
)

SMOKE = CONFIG.with_(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, n_experts=4, top_k=2, d_ff_expert=128,
    ssm_state=8, remat=False, capacity_factor=8.0)
