"""Config system: ModelConfig (architecture) + ShapeConfig (workload cell).

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``;
the four assigned input shapes are ``SHAPES`` below.  ``input_specs()``
produces ShapeDtypeStruct stand-ins (no allocation) for the dry-run.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # layer-kind pattern for ONE period; entries: "attn", "attn_local",
    # "mamba", "mlstm", "slstm"; "+moe" suffix swaps the MLP for MoE.
    pattern: Tuple[str, ...] = ("attn",)
    arch_class: str = "decoder"          # decoder | encdec
    family: str = "dense"                # dense | moe | hybrid | ssm | vlm | audio
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # pad the expert WEIGHT arrays to n_experts+padding (router stays at
    # n_experts; padded experts are never routed).  Lets a 16-∤ expert count
    # (qwen2-moe's 60) shard EP-cleanly over the 16-way model axis instead
    # of falling back to TP-in-expert (beyond-paper optimization, §Perf).
    expert_padding: int = 0
    # attention details
    window: int = 0                      # sliding window for attn_local
    attn_softcap: float = 0.0            # gemma-2 logit soft-capping
    final_softcap: float = 0.0
    qkv_bias: bool = False
    qk_norm: bool = False                # qwen3-style
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = () # qwen2-vl M-RoPE (t,h,w) head_dim split
    # SSM (mamba)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # enc-dec split (seamless): n_layers = n_enc + n_dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    sub_quadratic: bool = False          # eligible for long_500k
    remat: bool = True

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def rem_layers(self) -> int:
        return self.n_layers % self.period

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"
    accum_steps: int = 1 # gradient-accumulation microbatches (train only)


# The four assigned LM shapes (assignment block).  ``accum_steps`` here is a
# default; per-arch overrides live in the arch configs (memory-budget driven).
SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train", accum_steps=16),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input — no allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.arch_class == "encdec":
            # audio frontend stub: precomputed frame embeddings (assignment)
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, S // 4, cfg.d_model), jnp.bfloat16)
        if cfg.mrope_sections:
            batch["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.arch_class == "encdec":
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, S // 4, cfg.d_model), jnp.bfloat16)
        if cfg.mrope_sections:
            batch["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return batch
    # decode: one new token against a seq_len-deep cache
    batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.mrope_sections:
        batch["mrope_positions"] = jax.ShapeDtypeStruct((3, B, 1), i32)
    return batch


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Assignment skip rules (documented in DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: long_500k needs sub-quadratic "
                "attention (assignment rule)")
    return None
