"""qwen2-vl-72b [vlm] — M-RoPE (t/h/w rotary sections), dynamic-resolution
vision frontend STUBBED per assignment (input_specs provides patch
embeddings / position ids) [arXiv:2409.12191]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064,
    pattern=("attn",), qkv_bias=True,
    mrope_sections=(16, 24, 24), rope_theta=1e6,
    tie_embeddings=False, sub_quadratic=False,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, mrope_sections=(2, 3, 3), remat=False)
