"""deepseek-67b [dense] — 95-layer llama-arch GQA [arXiv:2401.02954]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=102400,
    pattern=("attn",),
    tie_embeddings=False, sub_quadratic=False,
)

SMOKE = CONFIG.with_(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, remat=False)
