"""seamless-m4t-large-v2 [audio] — encoder-decoder backbone; 24L total split
12 enc + 12 dec per the assigned config; audio frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2308.11596]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio", arch_class="encdec",
    n_layers=24, n_enc_layers=12, n_dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206,
    pattern=("attn",),
    tie_embeddings=True, sub_quadratic=False,
)

SMOKE = CONFIG.with_(
    n_layers=4, n_enc_layers=2, n_dec_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, remat=False)
