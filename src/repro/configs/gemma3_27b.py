"""gemma3-27b [dense] — 5:1 local:global attention, 128k context, QK-norm,
262k vocab [hf:google/gemma-3 family].  62 = 10 periods of 6 + 2 remainder
local layers (unrolled).  Single rope_theta=1e6 for both local and global
layers (simplification noted in DESIGN.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144,
    pattern=("attn_local",) * 5 + ("attn",),
    window=1024, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True, sub_quadratic=True,
)

SMOKE = CONFIG.with_(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, window=32, remat=False)
