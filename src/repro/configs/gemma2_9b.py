"""gemma2-9b [dense] — local/global alternating, logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000,
    pattern=("attn_local", "attn"),
    window=4096, attn_softcap=50.0, final_softcap=30.0,
    tie_embeddings=True, sub_quadratic=True,
)

SMOKE = CONFIG.with_(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, window=32, remat=False)
