"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab=151936,
    pattern=("attn+moe",),
    n_experts=60, top_k=4, n_shared_experts=4, d_ff_expert=1408,
    expert_padding=4,  # 60->64 weights: clean 16-way EP (see §Perf)
    qkv_bias=True,
    tie_embeddings=True, sub_quadratic=False,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    vocab=512, n_experts=6, top_k=2, n_shared_experts=1, d_ff_expert=64,
    remat=False, capacity_factor=8.0)
