"""Wall-clock span tracing with Chrome ``trace_event`` JSON export.

Events accumulate in memory as plain dicts and are written once at
shutdown — recording a span is two ``perf_counter`` reads and a list
append, cheap enough for per-chunk train phases and per-tick serve
loops (thousands of events, not millions).

The export is the Trace Event Format's JSON-object flavor::

    {"traceEvents": [{"name", "ph", "ts", "dur", "pid", "tid",
                      "cat", "args"}, ...],
     "displayTimeUnit": "ms", "otherData": {...}}

* complete spans: ``ph = "X"`` with ``ts``/``dur`` in microseconds,
* counters:       ``ph = "C"`` with the sampled values in ``args``,
* instants:       ``ph = "i"`` with scope ``"p"`` (process).

Open the file in https://ui.perfetto.dev or ``chrome://tracing``.
Timestamps are relative to tracer construction (``perf_counter`` is an
arbitrary-epoch monotonic clock); the wall-clock origin is recorded in
``otherData.t0_unix`` for correlation with JSONL metric ``ts`` fields.
"""

from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

PHASES = ("X", "C", "i")


class Tracer:
    """Collects trace events; thread-compat via the ``tid`` argument
    (callers pick stable small ints per logical lane)."""

    def __init__(self, process_name: str = "repro"):
        self.process_name = process_name
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._t0_unix = time.time()

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, cat: str = "train", tid: int = 0,
             **args: Any):
        """Complete-event span around a ``with`` body.  ``args`` given at
        entry land in the event; the body may add more via the yielded
        dict (e.g. a token count known only afterwards)."""
        ev_args = dict(args)
        t0 = self.now_us()
        try:
            yield ev_args
        finally:
            t1 = self.now_us()
            self.events.append({
                "name": name, "ph": "X", "ts": t0, "dur": t1 - t0,
                "pid": 0, "tid": tid, "cat": cat, "args": ev_args,
            })

    def counter(self, name: str, cat: str = "train", tid: int = 0,
                **values: Any) -> None:
        """Sampled counter track (queue depth, slot occupancy, ...)."""
        self.events.append({
            "name": name, "ph": "C", "ts": self.now_us(),
            "pid": 0, "tid": tid, "cat": cat,
            "args": {k: float(v) for k, v in values.items()},
        })

    def instant(self, name: str, cat: str = "train", tid: int = 0,
                **args: Any) -> None:
        self.events.append({
            "name": name, "ph": "i", "s": "p", "ts": self.now_us(),
            "pid": 0, "tid": tid, "cat": cat, "args": dict(args),
        })

    def export(self) -> Dict[str, Any]:
        """The Chrome trace JSON object (events sorted by ``ts`` plus a
        process-name metadata event so Perfetto labels the track)."""
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": self.process_name}}]
        return {
            "traceEvents": meta + sorted(self.events,
                                         key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
            "otherData": {"t0_unix": self._t0_unix},
        }

    def write(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.export(), f)
        return path


def validate(doc: Dict[str, Any]) -> None:
    """Schema check used by tests and the obs benchmark: raises
    ``ValueError`` on the first malformed event."""
    if not isinstance(doc.get("traceEvents"), list):
        raise ValueError("traceEvents missing or not a list")
    for i, ev in enumerate(doc["traceEvents"]):
        ph = ev.get("ph")
        if ph == "M":
            continue
        if ph not in PHASES:
            raise ValueError(f"event {i}: bad ph {ph!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"event {i}: bad name")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: bad dur {dur!r}")
        if "pid" not in ev or "tid" not in ev:
            raise ValueError(f"event {i}: missing pid/tid")
        json.dumps(ev.get("args", {}))
