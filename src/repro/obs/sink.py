"""Metric sinks and the process-global :class:`Telemetry` registry.

A *record* is one flat-ish JSON-serializable dict with a ``kind`` key
(``"train_step"``, ``"taps"``, ``"serve_request"``, ``"log"``, ...).
Sinks are dumb transports — no aggregation, no schema enforcement beyond
JSON serializability.  Aggregation belongs to whoever reads the file.

``JsonlSink`` writes a provenance *header* record first (``kind:
"run"``, carrying the same ``run_meta`` dict the checkpoint manifest
stores — data provenance, state codec, fine-tune config) and stamps
every subsequent record with a monotone ``seq``, so a metrics file is
attributable to its run without a side channel.  Each record is
flushed as it is written: a SIGKILLed run still leaves every completed
record readable.
"""

from __future__ import annotations

import json
import os
import time
from contextlib import nullcontext
from typing import Any, Dict, Optional, Protocol, runtime_checkable

from repro.obs.trace import Tracer


@runtime_checkable
class MetricSink(Protocol):
    """Transport for metric records: ``emit`` one dict, ``close`` once."""

    def emit(self, record: Dict[str, Any]) -> None: ...

    def close(self) -> None: ...


class NullSink:
    """Drops everything.  The default process-global sink."""

    enabled = False

    def emit(self, record: Dict[str, Any]) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink:
    """Keeps records in a list — tests and in-process consumers."""

    enabled = True

    def __init__(self) -> None:
        self.records: list = []
        self.closed = False

    def emit(self, record: Dict[str, Any]) -> None:
        self.records.append(dict(record))

    def close(self) -> None:
        self.closed = True


def _jsonable(x):
    """Best-effort coercion: numpy/jax scalars -> python, else repr."""
    if isinstance(x, (int, float, str, bool, type(None))):
        return x  # fast path: a per-field json.dumps probe costs more
        # than the whole record's final dumps on the train_step hot path
    try:
        json.dumps(x)
        return x
    except TypeError:
        pass
    item = getattr(x, "item", None)
    if item is not None and getattr(x, "ndim", 1) == 0:
        try:
            return item()
        except Exception:  # noqa: BLE001 - fall through to tolist/repr
            pass
    tolist = getattr(x, "tolist", None)
    if tolist is not None:
        try:
            return tolist()
        except Exception:  # noqa: BLE001
            pass
    return repr(x)


class JsonlSink:
    """One flushed JSON line per record under ``path``.

    ``run`` is the provenance dict (the checkpoint manifest's ``run``
    metadata); it is written once as the ``kind: "run"`` header record.
    Records are stamped with ``seq`` (monotone per sink) and, when the
    caller did not provide one, a wall-clock ``ts``.
    """

    enabled = True

    def __init__(self, path: str, run: Optional[Dict[str, Any]] = None):
        self.path = str(path)
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        self._f = open(self.path, "a", encoding="utf-8")
        self._seq = 0
        self._write({"kind": "run", "ts": time.time(),
                     "pid": os.getpid(), "run": run or {}})

    def _write(self, record: Dict[str, Any]) -> None:
        record = {k: _jsonable(v) for k, v in record.items()}
        record["seq"] = self._seq
        self._seq += 1
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()

    def emit(self, record: Dict[str, Any]) -> None:
        if self._f.closed:
            return
        if "ts" not in record:
            record = {**record, "ts": time.time()}
        self._write(record)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


_NULL_SPAN = nullcontext()


class Telemetry:
    """A sink plus an optional tracer behind no-op-safe entry points.

    Every method is safe (and near-free) when the backend is absent, so
    call sites never guard on enablement.
    """

    def __init__(self, sink: Optional[MetricSink] = None,
                 tracer: Optional[Tracer] = None,
                 trace_path: Optional[str] = None):
        self.sink: MetricSink = sink if sink is not None else NullSink()
        self.tracer = tracer
        self.trace_path = trace_path

    @property
    def enabled(self) -> bool:
        return getattr(self.sink, "enabled", True) or self.tracer is not None

    def emit(self, kind: str, **fields: Any) -> None:
        self.sink.emit({"kind": kind, **fields})

    def log(self, msg: str, kind: str = "log", **fields: Any) -> None:
        """Console backend: prints to stdout *and* records the same line,
        so the terminal transcript and the JSONL file agree."""
        print(msg)
        self.sink.emit({"kind": kind, "msg": msg, **fields})

    def span(self, name: str, cat: str = "train", tid: int = 0,
             **args: Any):
        if self.tracer is None:
            return _NULL_SPAN
        return self.tracer.span(name, cat=cat, tid=tid, **args)

    def counter(self, name: str, cat: str = "train", **values: Any) -> None:
        if self.tracer is not None:
            self.tracer.counter(name, cat=cat, **values)

    def close(self) -> None:
        if self.tracer is not None and self.trace_path:
            self.tracer.write(self.trace_path)
        self.sink.close()


_GLOBAL = Telemetry()


def get() -> Telemetry:
    """The process-global Telemetry (a null instance until configured)."""
    return _GLOBAL


def configure(metrics_dir: Optional[str] = None,
              run: Optional[Dict[str, Any]] = None,
              sink: Optional[MetricSink] = None,
              tracer: Optional[Tracer] = None,
              trace: bool = True) -> Telemetry:
    """Install the process-global Telemetry and return it.

    ``metrics_dir`` is the one-knob path: a :class:`JsonlSink` at
    ``<dir>/metrics.jsonl`` (header stamped with ``run``) plus a tracer
    exported to ``<dir>/trace.json`` on :func:`shutdown`.  Explicit
    ``sink``/``tracer`` override the dir-derived ones (tests).  With
    neither, installs a null Telemetry (useful to reset).
    """
    global _GLOBAL
    trace_path = None
    if metrics_dir is not None:
        os.makedirs(metrics_dir, exist_ok=True)
        if sink is None:
            sink = JsonlSink(os.path.join(metrics_dir, "metrics.jsonl"),
                             run=run)
        if tracer is None and trace:
            tracer = Tracer()
        trace_path = os.path.join(metrics_dir, "trace.json")
    if _GLOBAL.enabled:
        _GLOBAL.close()
    _GLOBAL = Telemetry(sink=sink, tracer=tracer, trace_path=trace_path)
    return _GLOBAL


def shutdown() -> None:
    """Close the global Telemetry (writes the trace file) and reset to
    the null instance."""
    global _GLOBAL
    _GLOBAL.close()
    _GLOBAL = Telemetry()
