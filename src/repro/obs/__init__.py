"""Unified telemetry: metric sinks, span tracing, and the process-global
:class:`Telemetry` registry.

Three layers, composable and individually optional:

* :mod:`repro.obs.sink` — the :class:`MetricSink` record protocol with
  JSONL (one flushed line per record: a killed run leaves a readable
  file), in-memory, and null backends.
* :mod:`repro.obs.trace` — wall-clock span/counter tracer exporting
  Chrome ``trace_event`` JSON (open in Perfetto / ``chrome://tracing``).
* :class:`Telemetry` — bundles a sink and a tracer behind no-op-safe
  ``emit`` / ``span`` / ``log`` entry points.  A process-global instance
  (:func:`configure` / :func:`get` / :func:`shutdown`) lets deep layers
  (train loop, serve engine, watchdog) report without plumbing a handle
  through every constructor.

The default global is a *null* Telemetry: ``emit`` drops the record,
``span`` yields a shared no-op context, ``log`` only prints.  Hot-path
call sites therefore never need an ``if enabled`` guard — the disabled
cost is one attribute load and a dict drop.  On-device tap *values* are
not routed through here at all (they live in the jitted step's metrics
output and are fetched at ``log_every`` boundaries by the train loop);
this layer only receives the already-fetched host scalars.
"""

from repro.obs.sink import (JsonlSink, MemorySink, MetricSink, NullSink,
                            Telemetry, configure, get, shutdown)
from repro.obs.trace import Tracer

__all__ = [
    "JsonlSink", "MemorySink", "MetricSink", "NullSink", "Telemetry",
    "Tracer", "configure", "get", "shutdown",
]
