"""Serving launcher: continuous-batching engine CLI plus the small
static-batch ``generate`` helper the tests and examples drive directly.

    # continuous batching over a slot-paged KV cache (DESIGN.md §9)
    PYTHONPATH=src python -m repro.launch.serve --arch llama-60m --smoke \
        --requests 16 --prompt-len 32 --gen 16 --num-slots 4

    # same, int8-quantized KV pages, serving a training checkpoint
    PYTHONPATH=src python -m repro.launch.serve --arch llama-60m --smoke \
        --ckpt runs/smoke/ckpt --kv-quant int8

The engine itself lives in :mod:`repro.serve.engine`; this module only
builds a workload and prints the stats.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm
from repro.runtime.context import MeshContext


def pad_cache(cache, max_len: int, window: int = 0):
    """Grow full-attention prefill caches (depth = prompt) to decode
    capacity ``max_len``.  Ring-buffer (window) caches stay at window size —
    their slot arithmetic requires prompt_len % window == 0 (asserted at
    prefill).

    KV leaves are identified by their dict key ('k'/'v' — unique to
    attention caches); the sequence axis is -3 of (…, S, KV, hd), which
    covers both scan-stacked (L, B, S, KV, hd) and flat (B, S, KV, hd)
    layouts.  Growing is one-way: leaves already at or above ``max_len``
    are left alone.  Callers about to decode should assert the result
    with :func:`ensure_capacity` — a decode write past the cache end
    silently clamps (wrong attention), it does not error.
    """
    def grow(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v") and x.ndim >= 4 \
                and x.shape[-3] < max_len and x.shape[-3] != window:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, max_len - x.shape[-3])
            return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(grow, cache)


def ensure_capacity(cache, needed: int, window: int = 0):
    """Raise unless every full-attention KV leaf can hold ``needed``
    positions.

    ``dynamic_update_slice`` CLAMPS out-of-bounds start indices instead of
    erroring, so a decode past an undersized cache quietly overwrites the
    last cache row — attention then reads a corrupted history and the
    failure surfaces as subtly wrong logits far from the cause.  This
    check turns that into a loud error at the call site.  Ring-buffer
    leaves (depth == ``window``) are exempt: they wrap by construction.
    Returns ``cache`` so it can wrap a cache expression in-line."""
    def check(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v") and x.ndim >= 4 \
                and x.shape[-3] != window and x.shape[-3] < needed:
            raise ValueError(
                f"KV cache depth {x.shape[-3]} < {needed} required: decode "
                f"writes past the end silently clamp (wrong attention) — "
                f"grow the cache with pad_cache(cache, {needed}) first")
        return x
    jax.tree_util.tree_map_with_path(check, cache)
    return cache


def generate(cfg, params, tokens, gen_len: int, greedy: bool = True,
             key=None, ctx: MeshContext = None):
    B, S = tokens.shape
    prefill = jax.jit(lm.make_prefill_step(cfg, ctx=ctx))
    decode = jax.jit(lm.make_decode_step(cfg, ctx=ctx))
    logits, cache = prefill(params, {"tokens": tokens})
    cache = ensure_capacity(pad_cache(cache, S + gen_len, window=cfg.window),
                            S + gen_len, window=cfg.window)
    out = []
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(gen_len):
        out.append(nxt)
        logits, cache = decode(params, cache, {"tokens": nxt})
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def build_workload(n: int, vocab: int, max_prompt: int, max_gen: int,
                   rate: float, seed: int):
    """Mixed-length serving workload: prompts uniform in
    [max_prompt//4, max_prompt]; generation lengths BIMODAL — 75% short
    (~max_gen/16..max_gen/8, chat-style turns) and 25% long
    (3·max_gen/4..max_gen, completion-style) — the length skew that makes
    static waves idle their short-request slots behind the long tail.
    ``rate`` > 0 adds Poisson (exponential inter-arrival) open-loop
    arrivals at that many req/s; 0 backlogs everything at t=0."""
    from repro.serve.engine import Request
    rng = np.random.RandomState(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        plen = int(rng.randint(max(1, max_prompt // 4), max_prompt + 1))
        if rng.rand() < 0.25:
            glen = int(rng.randint(max(2, 3 * max_gen // 4), max_gen + 1))
        else:
            glen = int(rng.randint(max(1, max_gen // 16),
                                   max(2, max_gen // 8) + 1))
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        reqs.append(Request(
            rid=i, prompt=rng.randint(0, vocab, size=plen).tolist(),
            max_gen=glen, arrival=t if rate > 0 else 0.0))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="training checkpoint dir to serve (params-only "
                         "load); default: random init")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate (req/s); "
                         "0 = backlogged")
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--kv-quant", default=None, choices=[None, "int8"])
    ap.add_argument("--eos-id", type=int, default=None,
                    help="retire a request early when it generates this "
                         "token (default: max_gen-bounded only)")
    ap.add_argument("--merge-lora", action="store_true",
                    help="treat --ckpt as a --finetune lora checkpoint: "
                         "restore {'base','lora'} and serve the merged "
                         "weights (auto-detected when the checkpoint's "
                         "run metadata records the fine-tune)")
    ap.add_argument("--lora-rank", type=int, default=8,
                    help="adapter rank for --merge-lora on checkpoints "
                         "without recorded fine-tune metadata")
    ap.add_argument("--lora-alpha", type=float, default=16.0)
    ap.add_argument("--static", action="store_true",
                    help="static-wave admission (the benchmark baseline)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "pallas", "interpret", "jnp"])
    ap.add_argument("--metrics-dir", default="",
                    help="telemetry directory (sibling of train "
                         "--metrics-dir): per-request JSONL records -> "
                         "<dir>/metrics.jsonl (emitted at retirement, so "
                         "a killed run keeps its completed requests) and "
                         "per-tick Chrome-trace spans/counters (queue "
                         "depth, slot occupancy, page-arena utilization) "
                         "-> <dir>/trace.json")
    args = ap.parse_args(argv)
    from repro import obs
    tel = obs.configure(args.metrics_dir or None,
                        run={"cmd": "serve", "arch": args.arch,
                             "ckpt": args.ckpt, "requests": args.requests,
                             "num_slots": args.num_slots,
                             "kv_quant": args.kv_quant,
                             "static": args.static, "seed": args.seed})
    ctx = MeshContext.create(kernel_impl=args.kernel_impl)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.arch_class == "encdec":
        raise SystemExit(
            "the serving engine is decoder-only; enc-dec decoding lives in "
            "repro.models.encdec.decode_stack (exercised by tests/"
            "test_models.py::test_encdec_decode_matches_teacher_forcing)")

    from repro.serve.engine import Engine, EngineConfig
    ecfg = EngineConfig(num_slots=args.num_slots, page_size=args.page_size,
                        max_ctx=args.prompt_len + args.gen,
                        prefill_chunk=args.prefill_chunk,
                        kv_quant=args.kv_quant, eos_id=args.eos_id)
    if args.ckpt:
        eng = Engine.from_checkpoint(
            cfg, args.ckpt, ecfg, ctx=ctx,
            merge_lora=True if args.merge_lora else None,
            lora_rank=args.lora_rank, lora_alpha=args.lora_alpha)
    else:
        eng = Engine(cfg, lm.init(cfg, jax.random.key(args.seed)), ecfg,
                     ctx=ctx)
    reqs = build_workload(args.requests, cfg.vocab, args.prompt_len,
                          args.gen, args.rate, args.seed)
    try:
        eng.warmup()
        stats = eng.run(reqs, static=args.static)
        stats["kv_arena_bytes"] = eng.kv_bytes()
        stats["mode"] = "static" if args.static else "continuous"
        tel.emit("serve_summary", **stats)
        print(json.dumps(stats, indent=2, sort_keys=True))
    finally:
        obs.shutdown()   # writes <metrics-dir>/trace.json
    return stats


if __name__ == "__main__":
    main()
