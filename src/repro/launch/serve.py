"""Batched serving driver: prefill + decode loop with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Covers the assignment's serve path end-to-end on CPU (smoke configs) and is
what the decode dry-run cells lower at production shape.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.runtime.context import MeshContext


def pad_cache(cache, max_len: int, window: int = 0):
    """Grow full-attention prefill caches (depth = prompt) to decode
    capacity ``max_len``.  Ring-buffer (window) caches stay at window size —
    their slot arithmetic requires prompt_len % window == 0 (asserted at
    prefill).

    KV leaves are identified by their dict key ('k'/'v' — unique to
    attention caches); the sequence axis is -3 of (…, S, KV, hd), which
    covers both scan-stacked (L, B, S, KV, hd) and flat (B, S, KV, hd)
    layouts.  A decode write past an unpadded cache silently clamps
    (wrong attention) — caught by test_decode_matches_full_forward.
    """
    def grow(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v") and x.ndim >= 4 \
                and x.shape[-3] < max_len and x.shape[-3] != window:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, max_len - x.shape[-3])
            return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(grow, cache)


def generate(cfg, params, tokens, gen_len: int, greedy: bool = True,
             key=None, ctx: MeshContext = None):
    B, S = tokens.shape
    prefill = jax.jit(lm.make_prefill_step(cfg, ctx=ctx))
    decode = jax.jit(lm.make_decode_step(cfg, ctx=ctx))
    logits, cache = prefill(params, {"tokens": tokens})
    cache = pad_cache(cache, S + gen_len, window=cfg.window)
    out = []
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(gen_len):
        out.append(nxt)
        logits, cache = decode(params, cache, {"tokens": nxt})
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "pallas", "interpret", "jnp"])
    args = ap.parse_args(argv)
    ctx = MeshContext.create(kernel_impl=args.kernel_impl)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.arch_class == "encdec":
        raise SystemExit("use examples/serve_encdec flow for enc-dec archs")
    key = jax.random.key(args.seed)
    params = lm.init(cfg, key)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    t0 = time.time()
    out = generate(cfg, params, tokens, args.gen, ctx=ctx)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0, :12].tolist())
    return out


if __name__ == "__main__":
    main()
