"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama-60m \
        --optimizer gwt --level 2 --steps 200 --batch 16 --seq 256 \
        --ckpt-dir /tmp/ckpt [--resume] [--data bytes]

Distributed (mesh-aware) training — the sharded path of DESIGN.md §3:

    python -m repro.launch.train ... --mesh 8 --dp-reduce compressed \
        --dp-level 2 [--dp-detail-dtype bfloat16] [--shard-params auto]

``--dp-reduce`` routes the data-parallel gradient reduction through
``shard_map`` + ``compressed_psum_mean`` (exact f32 psum or wavelet-
compressed wire format); ``--shard-params auto`` additionally pins
params/optimizer state to the FSDP/TP rule table.

On a real TPU pod this runs under ``jax.distributed.initialize()`` with the
production mesh; in the CPU container it runs single-device (or multi-device
via XLA_FLAGS) with the same code path.  Fault tolerance: SIGTERM →
synchronous checkpoint → exit 0; restart with ``--resume`` continues from
the latest committed step with the data stream aligned.
"""

from __future__ import annotations

import argparse
import math

import jax

from repro import configs, obs, optim
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import make_source
from repro.distributed.compression import DPReduceSpec
from repro.launch.mesh import make_mesh_context
from repro.models import encdec, lm
from repro.optim.schedules import warmup_cosine
from repro.runtime.fault_tolerance import TrainLoop


def make_optimizer(name: str, lr: float, steps: int, **kw) -> optim.Optimizer:
    sched = warmup_cosine(lr, steps)
    return optim.make(name, lr=sched, **kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--optimizer", default="gwt",
                    choices=["gwt", "adam", "adam_mini", "muon", "galore",
                             "apollo", "fira", "adarankgrad", "rso", "sgd"])
    ap.add_argument("--level", type=int, default=2)
    ap.add_argument("--host", default="adam",
                    choices=["adam", "adam_mini", "muon"])
    ap.add_argument("--state-codec", default="f32",
                    choices=["f32", "int8"],
                    help="optimizer-state substrate: 'f32' = raw moments "
                         "(bitwise-identical to the pre-codec engine), "
                         "'int8' = blocked 8-bit moments (per-64-block "
                         "absmax scale, stochastic rounding; composes "
                         "with any --optimizer).  --resume transcodes "
                         "when the checkpoint was written under the "
                         "other codec")
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--finetune", default="none", choices=["none", "lora"],
                    help="'lora': freeze the base model (zero optimizer "
                         "state via the engine's frozen rule) and train "
                         "injected low-rank adapters on the attention/MLP "
                         "projections; composes with any --optimizer/"
                         "--state-codec (the adapters' moments get "
                         "compressed/quantized)")
    ap.add_argument("--lora-rank", type=int, default=8)
    ap.add_argument("--lora-alpha", type=float, default=16.0)
    ap.add_argument("--base-ckpt", default="",
                    help="checkpoint dir holding the pre-trained base "
                         "(params-only restore via restore_params); with "
                         "--finetune lora the restored weights become the "
                         "frozen base")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "bytes", "corpus"])
    ap.add_argument("--corpus-dir", default="",
                    help="with --data corpus: a directory built by "
                         "`python -m repro.data.build_corpus` (mmap "
                         "token shards + index)")
    ap.add_argument("--workers", type=int, default=0,
                    help="data-loader worker PROCESSES (shared-memory "
                         "transport; 0 = in-process prefetch thread).  "
                         "Batches are a pure function of the step, so "
                         "worker count never changes the stream — safe "
                         "to vary across resumes")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="evaluate held-out loss/perplexity every N "
                         "steps (corpus eval split, or a disjoint "
                         "synthetic stream); 0 disables")
    ap.add_argument("--eval-batches", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="elastic mesh, e.g. '4x2' over (data, model); "
                         "empty = single device (or all devices over "
                         "'data' when --dp-reduce is set)")
    ap.add_argument("--dp-reduce", default="none",
                    choices=["none", "exact", "compressed"],
                    help="mesh-aware DP gradient reduction: 'exact' = f32 "
                         "psum inside shard_map, 'compressed' = wavelet "
                         "split (f32 approximation band, --dp-detail-dtype "
                         "details); 'none' keeps the auto-sharded step")
    ap.add_argument("--dp-level", type=int, default=2,
                    help="wavelet levels for --dp-reduce compressed "
                         "(wire bytes ~ 1/2^l f32 + (1-1/2^l) detail)")
    ap.add_argument("--dp-detail-dtype", default="bfloat16",
                    choices=["bfloat16", "float16", "float8_e4m3fn"],
                    help="detail-band wire dtype for --dp-reduce "
                         "compressed (the psum ships this dtype)")
    ap.add_argument("--dp-error-feedback", action="store_true",
                    help="with --dp-reduce compressed: keep each "
                         "device's quantization residue and add it back "
                         "before the next reduction (the compressed "
                         "mean's bias averages out instead of "
                         "persisting)")
    ap.add_argument("--shard-params", default="auto",
                    choices=["auto", "none"],
                    help="with --dp-reduce only (no effect otherwise — "
                         "plain mesh runs stay GSPMD-auto-sharded): "
                         "'auto' pins params/opt-state to the FSDP rule "
                         "table, 'none' keeps them replicated (classic "
                         "DP — the layout whose numerics are independent "
                         "of device count)")
    ap.add_argument("--no-donate", action="store_true",
                    help="keep (params, opt_state) undonated in the "
                         "pipelined loop.  Donation changes XLA's fusion "
                         "(and hence float rounding) per topology, so "
                         "cross-device-count bitwise reproducibility "
                         "requires it off; same-topology runs are "
                         "deterministic either way")
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "pallas", "interpret", "jnp"],
                    help="fused-kernel backend (auto: pallas on TPU, "
                         "jnp elsewhere; REPRO_KERNEL_IMPL also works)")
    ap.add_argument("--metrics-dir", default="",
                    help="telemetry directory (DESIGN.md §12): JSONL "
                         "metric records -> <dir>/metrics.jsonl, Chrome-"
                         "trace spans -> <dir>/trace.json (open in "
                         "Perfetto), and the on-device training-dynamics "
                         "taps (band energy, clip rate, update norms) "
                         "joined to the step metrics.  Unset: telemetry "
                         "compiles away — training numerics stay "
                         "bitwise-identical")
    args = ap.parse_args(argv)

    tel = obs.configure(args.metrics_dir or None,
                        run={"cmd": "train", "arch": args.arch,
                             "optimizer": args.optimizer,
                             "level": args.level, "host": args.host,
                             "state_codec": args.state_codec,
                             "steps": args.steps, "seed": args.seed,
                             "finetune": args.finetune})

    dp_spec = DPReduceSpec.parse(args.dp_reduce, args.dp_level,
                                 args.dp_detail_dtype,
                                 error_feedback=args.dp_error_feedback)
    if args.mesh:
        try:
            shape = tuple(int(s) for s in args.mesh.lower().split("x"))
        except ValueError:
            ap.error(f"--mesh {args.mesh!r}: expected integers joined by "
                     "'x', e.g. '8' or '4x2' or '2x4x2'")
        if not 1 <= len(shape) <= 3:
            ap.error(f"--mesh {args.mesh!r}: 1-3 axes supported "
                     "((data), (data, model), (pod, data, model))")
        axes = (("data",), ("data", "model"),
                ("pod", "data", "model"))[len(shape) - 1]
        ctx = make_mesh_context(shape, axes, kernel_impl=args.kernel_impl)
    elif dp_spec is not None:
        # mesh-aware reduction without an explicit shape: all devices DP
        ctx = make_mesh_context((jax.device_count(),), ("data",),
                                kernel_impl=args.kernel_impl)
    else:
        ctx = make_mesh_context(kernel_impl=args.kernel_impl)
    if dp_spec is not None and ctx.auto_axis_names:
        ap.error(f"--dp-reduce {args.dp_reduce} needs a pure-DP mesh "
                 f"(single-axis '--mesh 8'), not {args.mesh!r}: the "
                 f"manual DP reduction cannot leave {ctx.auto_axis_names} "
                 f"to GSPMD on this JAX — drop --dp-reduce for TP meshes")

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.data == "corpus":
        # the embedding table must cover the corpus tokenizer: vocab is a
        # property of the data, so the model grows to fit (never shrinks)
        from repro.data.store import TokenStore
        if not args.corpus_dir:
            ap.error("--data corpus needs --corpus-dir (build one with "
                     "`python -m repro.data.build_corpus`)")
        corpus_vocab = TokenStore(args.corpus_dir).vocab_size
        if corpus_vocab > cfg.vocab:
            tel.log(f"model vocab {cfg.vocab} -> {corpus_vocab} "
                    f"(corpus tokenizer)", kind="vocab_grow",
                    old=cfg.vocab, new=corpus_vocab)
            cfg = cfg.with_(vocab=corpus_vocab)
    mod = encdec if cfg.arch_class == "encdec" else lm
    key = jax.random.key(args.seed)
    params = mod.init(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))

    finetune_lora = args.finetune == "lora"
    if finetune_lora and dp_spec is not None:
        ap.error("--finetune lora does not compose with --dp-reduce yet "
                 "(the sharded step reduces full-tree gradients; adapter-"
                 "only reduction is future work) — drop --dp-reduce")
    if args.base_ckpt:
        base_params, base_step = CheckpointManager(
            args.base_ckpt).restore_params(None, params)
        params = base_params
        tel.log(f"restored pre-trained base from {args.base_ckpt} "
                f"(step {base_step})", kind="base_restore",
                ckpt=args.base_ckpt, step=base_step)

    # Encoder-decoder batches carry the audio-frontend frame stub; the
    # adapter lives in the pipeline (WithEncoderFrames), not a monkey-patch.
    enc = cfg.arch_class == "encdec"
    source = make_source(args.data, cfg.vocab, args.seq, args.batch,
                         seed=args.seed, corpus_dir=args.corpus_dir,
                         enc_frames=args.seq // 4 if enc else 0,
                         enc_dim=cfg.d_model if enc else 0)

    # Data provenance stamped into every checkpoint manifest: a resume on
    # a different corpus (or order seed) must fail loudly, not train on.
    data_meta = {"kind": args.data, "order_seed": args.seed}
    if args.data == "corpus":
        data_meta["corpus_hash"] = source.store.corpus_hash \
            if not enc else source.source.store.corpus_hash

    # Mesh mode: build the three sharding trees once (params, opt state,
    # batch) and hand the GWT engine its per-bucket hints before init.
    shardings = None
    if dp_spec is not None:
        from repro.distributed import sharding as shr
        b0 = source.batch(0)
        batch_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                     for k, v in b0.items()}
        shardings = shr.train_step_shardings(
            cfg, mod, batch_abs, ctx.mesh, optimizer_name=args.optimizer,
            level=args.level, host=args.host,
            shard_params=args.shard_params == "auto",
            state_codec=args.state_codec)

    opt_kw = {"state_codec": args.state_codec}
    if args.optimizer == "gwt":
        opt_kw.update({"level": args.level, "alpha": args.alpha,
                       "host": args.host, "impl": ctx.kernel_impl})
        if shardings is not None and shardings.opt is not None:
            opt_kw["state_shardings"] = shardings.opt["buckets"]
    elif args.optimizer in ("galore", "apollo", "fira", "adarankgrad",
                            "rso"):
        opt_kw.update({"rank_frac": 0.25, "alpha": args.alpha})
    optimizer = make_optimizer(args.optimizer, args.lr, args.steps, **opt_kw)

    base_like = params  # full-Adam reference below counts the raw model
    if finetune_lora:
        from repro.models import lora
        params = lora.inject(params, args.lora_rank,
                             jax.random.fold_in(key, 777))
        optimizer = lora.wrap_optimizer(optimizer)
        n_adapter = sum(x.size for x in jax.tree.leaves(params["lora"]))
        tel.log(f"finetune=lora rank={args.lora_rank} "
                f"alpha={args.lora_alpha} "
                f"adapters={n_adapter/1e3:.1f}K params "
                f"({n_adapter/max(n_params, 1):.4f} of base)",
                kind="finetune", rank=args.lora_rank,
                alpha=args.lora_alpha, adapter_params=n_adapter)

    opt_shardings = None
    if shardings is not None:
        from repro.distributed.sharding import replicated_like
        params = jax.device_put(params, shardings.params)
        opt_shardings = shardings.opt if shardings.opt is not None else \
            replicated_like(jax.eval_shape(optimizer.init, params), ctx.mesh)
    with ctx.activate():
        opt_state = optimizer.init(params)
    if opt_shardings is not None:
        opt_state = jax.device_put(opt_state, opt_shardings)

    # Error feedback rides OUTSIDE the optimizer state proper:
    # opt_state = {"opt": ..., "dp_ef": per-device residue} (the sharded
    # step unwraps it; checkpoints save/restore the wrapped tree whole).
    ef_wrap = dp_spec is not None and dp_spec.error_feedback
    if ef_wrap:
        from repro.distributed import compression as dcomp
        ef0 = dcomp.ef_init(params, ctx.dp_size)
        ef_sh = dcomp.ef_state_shardings(ef0, ctx.mesh, ctx.dp_axis_names)
        ef0 = jax.device_put(ef0, ef_sh)
        opt_state = {"opt": opt_state, "dp_ef": ef0}
        opt_shardings = {"opt": opt_shardings, "dp_ef": ef_sh}

    # Exact accounting for the *actual* optimizer/host (eval_shape over the
    # real init — no Adam-shaped approximation for non-GWT runs), plus the
    # compound compression factor vs the full-Adam f32 reference point the
    # paper's memory tables are normalized to.
    from repro.optim.engine import state_bytes
    mem_bytes = state_bytes(optimizer, params)
    adam_f32_bytes = state_bytes(optim.make("adam", lr=args.lr), base_like)
    tel.log(f"arch={cfg.name} params={n_params/1e6:.1f}M "
            f"optimizer={args.optimizer} codec={args.state_codec} "
            f"opt_state={mem_bytes/2**20:.2f}MiB "
            f"({adam_f32_bytes/max(mem_bytes, 1):.1f}x smaller than "
            f"full-Adam f32 {adam_f32_bytes/2**20:.2f}MiB)",
            kind="memory", params=n_params, opt_state_bytes=mem_bytes,
            adam_f32_bytes=adam_f32_bytes)
    if dp_spec is not None:
        from repro.distributed.compression import tree_wire_bytes
        grads_abs = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
        full = tree_wire_bytes(grads_abs, None)
        now = tree_wire_bytes(grads_abs, dp_spec)
        tel.log(f"dp_reduce={args.dp_reduce} dp={ctx.dp_size} "
                f"wire={now/2**20:.1f}MiB/step vs exact "
                f"{full/2**20:.1f}MiB ({full/now:.2f}x)",
                kind="dp_wire", wire_bytes=now, exact_bytes=full)

    # Raw (un-jitted) step: TrainLoop compiles it inside its donated
    # scan-over-chunk superstep (runtime/fault_tolerance.py).
    tap_step = None
    if finetune_lora:
        from repro.models import lora
        train_step = lora.make_train_step(mod, cfg, optimizer,
                                          rank=args.lora_rank,
                                          alpha=args.lora_alpha,
                                          accum_steps=args.accum, ctx=ctx)
    else:
        # on-device taps ride with --metrics-dir; the sharded dp_reduce
        # step has no tapped channel yet, so mesh runs keep spans/records
        # but skip taps.  The tapped variant is a SECOND step fn handed
        # to TrainLoop: it runs only on each chunk's boundary step, so
        # the tap reductions never touch the scanned hot path.
        step_kw = dict(accum_steps=args.accum, ctx=ctx,
                       dp_reduce=dp_spec, shardings=shardings)
        train_step = mod.make_train_step(cfg, optimizer, **step_kw)
        if args.metrics_dir and dp_spec is None \
                and getattr(optimizer, "tapped_update", None) is not None:
            tap_step = mod.make_train_step(cfg, optimizer, taps=True,
                                           **step_kw)
    run_meta = {"data": data_meta, "state_codec": args.state_codec}
    if finetune_lora:
        # serving reads this to auto-merge the adapters back into the
        # base weights (Engine.from_checkpoint / serve --merge-lora)
        run_meta["finetune"] = {"mode": "lora", "rank": args.lora_rank,
                                "alpha": args.lora_alpha}
    ckpt = CheckpointManager(args.ckpt_dir, run_meta=run_meta) \
        if args.ckpt_dir else None
    # stamp the metrics stream with the same provenance the checkpoint
    # manifest records (data hash, codec, finetune config)
    tel.emit("run_meta", **run_meta)
    start = 0
    if args.resume and ckpt is not None and ckpt.latest_step() is not None:
        from repro.checkpoint.manager import StructureMismatch
        saved_data = ckpt.manifest().get("run", {}).get("data")
        if saved_data is not None:
            for k in ("kind", "corpus_hash", "order_seed"):
                if k in saved_data and saved_data[k] != data_meta.get(k):
                    raise SystemExit(
                        f"--resume provenance mismatch: checkpoint in "
                        f"{ckpt.dir} was trained with data {k}="
                        f"{saved_data[k]!r}, this run has "
                        f"{data_meta.get(k)!r} — refusing to continue on "
                        f"a different data stream")
        restore_sh = None if shardings is None else \
            {"params": shardings.params, "opt": opt_shardings}
        try:
            (state, start) = ckpt.restore(None, {"params": params,
                                                 "opt": opt_state},
                                          shardings=restore_sh, ctx=ctx)
        except StructureMismatch as e:
            # Two recoverable shapes of mismatch: a pre-engine checkpoint
            # (per-leaf tuple optimizer state, "'leaves'" in its treedef)
            # and a codec change (the saved manifest's run.state_codec
            # differs from --state-codec).  Anything else means the
            # optimizer/model config changed since the save — report
            # that, don't guess.  (Error-feedback runs postdate the
            # legacy layout and stay unmigrated either way.)
            from repro.optim import engine as engine_mod
            saved_codec = ckpt.saved_run().get("state_codec", "f32")
            legacy = "'leaves'" in ckpt.manifest().get("treedef", "")
            if ef_wrap or not (legacy or saved_codec != args.state_codec):
                raise StructureMismatch(
                    f"checkpoint in {ckpt.dir} is bucketed but does not "
                    f"match this run's optimizer state — did --optimizer/"
                    f"--level/--host or the model config change since it "
                    f"was saved? ({e})") from e
            if legacy:
                # legacy layouts are raw f32 by construction
                like = optimizer.engine.legacy_like(params)
            else:
                saved_opt = make_optimizer(args.optimizer, args.lr,
                                           args.steps,
                                           **{**opt_kw,
                                              "state_codec": saved_codec})
                like = jax.eval_shape(saved_opt.init, params)
            (state, start) = ckpt.restore(None, {"params": params,
                                                 "opt": like}, ctx=ctx)
            if legacy:
                state["opt"] = optimizer.engine.migrate_legacy(state["opt"],
                                                               params)
                tel.log("migrated legacy per-leaf optimizer state -> "
                        "buckets", kind="migrate")
                if args.state_codec != "f32":
                    f32_opt = make_optimizer(args.optimizer, args.lr,
                                             args.steps,
                                             **{**opt_kw,
                                                "state_codec": "f32"})
                    state["opt"] = engine_mod.transcode(
                        state["opt"], params, f32_opt, optimizer)
                    tel.log(f"transcoded optimizer state f32 -> "
                            f"{args.state_codec}", kind="transcode",
                            src="f32", dst=args.state_codec)
            else:
                state["opt"] = engine_mod.transcode(
                    state["opt"], params, saved_opt, optimizer)
                tel.log(f"transcoded optimizer state {saved_codec} -> "
                        f"{args.state_codec}", kind="transcode",
                        src=saved_codec, dst=args.state_codec)
                if opt_shardings is not None:
                    state["opt"] = jax.device_put(state["opt"],
                                                  opt_shardings)
        params, opt_state = state["params"], state["opt"]
        tel.log(f"resumed from step {start}", kind="resume", step=start)

    evaluator = None
    if args.eval_every:
        from repro.data.eval import make_lm_evaluator
        eval_src = make_source(args.data, cfg.vocab, args.seq, args.batch,
                               seed=args.seed, corpus_dir=args.corpus_dir,
                               split="eval",
                               enc_frames=args.seq // 4 if enc else 0,
                               enc_dim=cfg.d_model if enc else 0)
        eval_mod = mod
        if finetune_lora:
            from repro.models import lora
            eval_mod = lora.loss_module(mod, args.lora_alpha, args.lora_rank)
        evaluator = make_lm_evaluator(cfg, eval_mod, eval_src,
                                      n_batches=args.eval_batches, ctx=ctx)

    loop = TrainLoop(train_step, ckpt, source, ckpt_every=args.ckpt_every,
                     log_every=args.log_every, save_final=ckpt is not None,
                     donate=not args.no_donate,
                     num_workers=args.workers,
                     evaluator=evaluator, eval_every=args.eval_every,
                     batch_shardings=None if shardings is None
                     else shardings.batch, tap_step=tap_step)
    try:
        with ctx.activate():
            params, opt_state, losses = loop.run(params, opt_state,
                                                 start_step=start,
                                                 num_steps=args.steps)
        wd = loop.watchdog.summary()
        if wd["dispatch_s_per_step"] is not None:
            print(f"dispatch={wd['dispatch_s_per_step']*1e3:.1f}ms/step "
                  f"blocked={(wd['blocked_s_per_step'] or 0)*1e3:.1f}"
                  f"ms/step incidents={wd['incidents']}")
        if losses:
            k = max(1, len(losses) // 10)
            tel.log(f"final loss (mean of last {k}): "
                    f"{sum(losses[-k:]) / k:.4f}", kind="final_loss",
                    loss=sum(losses[-k:]) / k, window=k)
        if evaluator is not None and evaluator.history:
            s, v = evaluator.history[-1]
            tel.log(f"final eval (step {s}): loss={v:.4f} "
                    f"ppl={math.exp(min(v, 30.0)):.2f}", kind="final_eval",
                    step=s, loss=float(v))
    finally:
        # writes <metrics-dir>/trace.json and closes the JSONL sink (a
        # no-op for the null telemetry); resets the process-global handle
        obs.shutdown()
    return params, opt_state, losses


if __name__ == "__main__":
    main()
