"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama-60m \
        --optimizer gwt --level 2 --steps 200 --batch 16 --seq 256 \
        --ckpt-dir /tmp/ckpt [--resume] [--data bytes]

On a real TPU pod this runs under ``jax.distributed.initialize()`` with the
production mesh; in the CPU container it runs single-device (or multi-device
via XLA_FLAGS) with the same code path.  Fault tolerance: SIGTERM →
synchronous checkpoint → exit 0; restart with ``--resume`` continues from
the latest committed step with the data stream aligned.
"""

from __future__ import annotations

import argparse

import jax

from repro import configs, optim
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import make_source
from repro.launch.mesh import make_mesh_context
from repro.models import encdec, lm
from repro.optim.schedules import warmup_cosine
from repro.runtime.fault_tolerance import TrainLoop


def make_optimizer(name: str, lr: float, steps: int, **kw) -> optim.Optimizer:
    sched = warmup_cosine(lr, steps)
    return optim.make(name, lr=sched, **kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--optimizer", default="gwt",
                    choices=["gwt", "adam", "adam_mini", "muon", "galore",
                             "apollo", "fira", "sgd"])
    ap.add_argument("--level", type=int, default=2)
    ap.add_argument("--host", default="adam",
                    choices=["adam", "adam_mini", "muon"])
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "bytes"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="elastic mesh, e.g. '4x2' over (data, model); "
                         "empty = single device")
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "pallas", "interpret", "jnp"],
                    help="fused-kernel backend (auto: pallas on TPU, "
                         "jnp elsewhere; REPRO_KERNEL_IMPL also works)")
    args = ap.parse_args(argv)

    if args.mesh:
        try:
            shape = tuple(int(s) for s in args.mesh.lower().split("x"))
        except ValueError:
            ap.error(f"--mesh {args.mesh!r}: expected integers joined by "
                     "'x', e.g. '8' or '4x2' or '2x4x2'")
        if not 1 <= len(shape) <= 3:
            ap.error(f"--mesh {args.mesh!r}: 1-3 axes supported "
                     "((data), (data, model), (pod, data, model))")
        axes = (("data",), ("data", "model"),
                ("pod", "data", "model"))[len(shape) - 1]
        ctx = make_mesh_context(shape, axes, kernel_impl=args.kernel_impl)
    else:
        ctx = make_mesh_context(kernel_impl=args.kernel_impl)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mod = encdec if cfg.arch_class == "encdec" else lm
    key = jax.random.key(args.seed)
    params = mod.init(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))

    opt_kw = {}
    if args.optimizer == "gwt":
        opt_kw = {"level": args.level, "alpha": args.alpha, "host": args.host,
                  "impl": ctx.kernel_impl}
    elif args.optimizer in ("galore", "apollo", "fira"):
        opt_kw = {"rank_frac": 0.25, "alpha": args.alpha}
    optimizer = make_optimizer(args.optimizer, args.lr, args.steps, **opt_kw)
    opt_state = optimizer.init(params)

    # Exact accounting for the *actual* optimizer/host (eval_shape over the
    # real init — no Adam-shaped approximation for non-GWT runs).
    from repro.optim.engine import state_bytes
    mem_bytes = state_bytes(optimizer, params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"optimizer={args.optimizer} opt_state={mem_bytes/2**20:.1f}MiB")

    # Encoder-decoder batches carry the audio-frontend frame stub; the
    # adapter lives in the pipeline (WithEncoderFrames), not a monkey-patch.
    enc = cfg.arch_class == "encdec"
    source = make_source(args.data, cfg.vocab, args.seq, args.batch,
                         seed=args.seed,
                         enc_frames=args.seq // 4 if enc else 0,
                         enc_dim=cfg.d_model if enc else 0)

    # Raw (un-jitted) step: TrainLoop compiles it inside its donated
    # scan-over-chunk superstep (runtime/fault_tolerance.py).
    train_step = mod.make_train_step(cfg, optimizer, accum_steps=args.accum,
                                     ctx=ctx)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.resume and ckpt is not None and ckpt.latest_step() is not None:
        from repro.checkpoint.manager import StructureMismatch
        try:
            (state, start) = ckpt.restore(None, {"params": params,
                                                 "opt": opt_state}, ctx=ctx)
        except StructureMismatch as e:
            # Only a pre-engine checkpoint (per-leaf tuple optimizer state,
            # "'leaves'" in its treedef) gets the migration path; a
            # mismatching *bucketed* checkpoint means the optimizer/model
            # config changed since the save — report that, don't guess.
            if "'leaves'" not in ckpt.manifest().get("treedef", ""):
                raise StructureMismatch(
                    f"checkpoint in {ckpt.dir} is bucketed but does not "
                    f"match this run's optimizer state — did --optimizer/"
                    f"--level/--host or the model config change since it "
                    f"was saved? ({e})") from e
            legacy = optimizer.engine.legacy_like(params)
            (state, start) = ckpt.restore(None, {"params": params,
                                                 "opt": legacy}, ctx=ctx)
            state["opt"] = optimizer.engine.migrate_legacy(state["opt"],
                                                           params)
            print("migrated legacy per-leaf optimizer state -> buckets")
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start}")

    loop = TrainLoop(train_step, ckpt, source, ckpt_every=args.ckpt_every,
                     log_every=args.log_every, save_final=ckpt is not None)
    with ctx.activate():
        params, opt_state, losses = loop.run(params, opt_state,
                                             start_step=start,
                                             num_steps=args.steps)
    wd = loop.watchdog.summary()
    if wd["dispatch_s_per_step"] is not None:
        print(f"dispatch={wd['dispatch_s_per_step']*1e3:.1f}ms/step "
              f"blocked={(wd['blocked_s_per_step'] or 0)*1e3:.1f}ms/step "
              f"incidents={wd['incidents']}")
    if losses:
        k = max(1, len(losses) // 10)
        print(f"final loss (mean of last {k}): "
              f"{sum(losses[-k:]) / k:.4f}")
    return params, opt_state, losses


if __name__ == "__main__":
    main()
