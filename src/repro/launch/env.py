"""Production TPU launch environment (compute/comm overlap).

The dry-run container has no TPU, so these cannot be measured here — they
are the shipped defaults for real-pod launches (standard latency-hiding
scheduler + async collective settings used by MaxText-class frameworks).
``apply()`` merges them into ``LIBTPU_INIT_ARGS``/``XLA_FLAGS`` without
clobbering user-set values.
"""

from __future__ import annotations

import os

TPU_XLA_FLAGS = [
    # overlap collectives with compute (latency-hiding scheduler)
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_enable_async_collective_fusion_multiple_steps=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
    # memory scheduler headroom for the overlapped buffers
    "--xla_tpu_scheduler_percent_shared_memory_limit=100",
]


def apply(env: dict = None) -> dict:
    env = env if env is not None else os.environ
    existing = env.get("XLA_FLAGS", "")
    merged = [f for f in TPU_XLA_FLAGS if f.split("=")[0] not in existing]
    env["XLA_FLAGS"] = (existing + " " + " ".join(merged)).strip()
    return env
