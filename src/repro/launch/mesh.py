"""Production mesh construction (assignment-mandated shapes).

FUNCTIONS, not module constants — importing this module never touches
jax device state.  All mesh construction routes through
:mod:`repro.compat` so the same code runs on jax 0.4.x–0.6.x.
"""

from __future__ import annotations

from repro import compat
from repro.runtime.context import MeshContext


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic helper: any factorization of the available devices works;
    checkpoint restore re-shards on load (see repro.checkpoint)."""
    return compat.make_mesh(tuple(shape), tuple(axes))


def make_mesh_context(shape=None, axes=None, *, multi_pod: bool = False,
                      production: bool = False,
                      kernel_impl: str = "auto") -> MeshContext:
    """One-stop launch helper: build the mesh and wrap it in the explicit
    :class:`MeshContext` threaded through model/optimizer/checkpoint.

    ``shape``/``axes`` build an elastic mesh; ``production=True`` builds the
    assignment-mandated pod mesh; neither gives a single-device context
    (every sharding constraint becomes a no-op — the CPU path)."""
    if shape is not None:
        mesh = make_mesh(shape, axes)
    elif production:
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:
        mesh = None
    return MeshContext.create(mesh=mesh, kernel_impl=kernel_impl)
