import os
import sys


def _jax_backend_uninitialized() -> bool:
    """XLA reads XLA_FLAGS at first *backend init*, not at jax import —
    so the fake-device request below is effective (and worth setting) any
    time before that, and pure pollution after (it would only leak into
    child-process environments, e.g. the test suite's subprocesses)."""
    if "jax" not in sys.modules:
        return True
    try:
        from jax._src import xla_bridge
        return not xla_bridge._backends
    except Exception:
        return False


if _jax_backend_uninitialized():
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape × mesh): build abstract params +
optimizer state + inputs (ShapeDtypeStruct — zero allocation), lower the
step function with explicit in/out shardings, ``.compile()``, and record
``memory_analysis()`` / ``cost_analysis()`` / parsed-HLO roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch jamba-v0.1-52b \
        --shape train_4k [--multipod] [--out out.json] [--level 3]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat, configs
from repro.core.gwt import gwt as gwt_optimizer
from repro.distributed import sharding as shr
from repro.launch.mesh import make_production_mesh
from repro.models import encdec, lm
from repro.runtime.context import MeshContext


def _decode_fill(shape):
    """Cache depth for decode cells: 'one new token with a KV cache of
    seq_len' — the new token lands in the last slot."""
    return shape.seq_len


def build_cell(cfg, shape, mesh, *, gwt_level: int = 2, optimizer=None,
               rules_override=None, ctx: MeshContext = None):
    """Returns (fn, args, in_shardings, out_shardings) ready to lower."""
    if ctx is None:
        ctx = MeshContext.create(mesh=mesh)
    is_encdec = cfg.arch_class == "encdec"
    mod = encdec if is_encdec else lm
    params_abs = mod.abstract_params(cfg)
    params_axes = mod.param_axes(cfg)
    batch_abs = configs.input_specs(cfg, shape)
    batch_sh = shr.batch_shardings(batch_abs, mesh)

    if shape.kind == "train":
        rules = rules_override or shr.train_rules(mesh)
        params_sh = shr.tree_shardings(params_abs, params_axes, mesh, rules)
        opt = optimizer or gwt_optimizer(
            lr=1e-2, level=gwt_level, alpha=0.25, state_dtype=jnp.bfloat16)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = shr.gwt_state_shardings(params_abs, params_axes, mesh, rules,
                                         gwt_level)
        dp = mesh.shape["data"] * mesh.shape.get("pod", 1)
        accum = max(1, min(shape.accum_steps, shape.global_batch // dp))
        fn = mod.make_train_step(cfg, opt, accum_steps=accum, ctx=ctx)
        args = (params_abs, opt_abs, batch_abs)
        in_sh = (params_sh, opt_sh, batch_sh)
        out_sh = (params_sh, opt_sh, None)
        return fn, args, in_sh, out_sh, {"accum_steps": accum}

    rules = rules_override or shr.decode_rules(mesh)
    params_sh = shr.tree_shardings(params_abs, params_axes, mesh, rules)
    if shape.kind == "prefill":
        fn = mod.make_prefill_step(cfg, ctx=ctx)
        return fn, (params_abs, batch_abs), (params_sh, batch_sh), None, {}

    # decode
    fill = _decode_fill(shape)
    if is_encdec:
        cache_abs = mod.abstract_cache(cfg, shape.global_batch, fill,
                                       enc_len=shape.seq_len // 4)
        cache_ax = mod.cache_axes(cfg)
    else:
        cache_abs = mod.abstract_cache(cfg, shape.global_batch, fill)
        cache_ax = mod.cache_axes(cfg)
    cache_sh = shr.tree_shardings(cache_abs, cache_ax, mesh, rules)
    fn = mod.make_decode_step(cfg, ctx=ctx)
    args = (params_abs, cache_abs, batch_abs)
    in_sh = (params_sh, cache_sh, batch_sh)
    out_sh = (None, cache_sh)
    return fn, args, in_sh, out_sh, {}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             gwt_level: int = 2, save_hlo: str = "", verbose: bool = True):
    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    skip = configs.skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skip", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = MeshContext.create(mesh=mesh)
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, meta = build_cell(cfg, shape, mesh,
                                                   gwt_level=gwt_level,
                                                   ctx=ctx)
        # donation: params+opt_state (train) / cache (decode) alias in place
        donate = (0, 1) if shape.kind == "train" \
            else ((1,) if shape.kind == "decode" else ())
        with ctx.activate():
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compat.cost_analysis(compiled)
            hlo = compiled.as_text()
    except Exception as e:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:]}

    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    "..", "..", ".."))
    from benchmarks import hlo_analysis
    n_chips = mesh.devices.size
    io_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                - mem.alias_size_in_bytes)
    roof = hlo_analysis.analyze(hlo, n_chips=n_chips, cost_analysis=cost,
                                io_bytes=max(io_bytes, 0))
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "n_chips": n_chips, **meta,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "total_bytes_per_device": (mem.argument_size_in_bytes
                                       + mem.output_size_in_bytes
                                       + mem.temp_size_in_bytes
                                       - mem.alias_size_in_bytes),
        },
        "hbm_budget_bytes": 16 * 1024 ** 3,
        "roofline": roof,
    }
    result["fits_hbm"] = result["memory"]["total_bytes_per_device"] \
        < result["hbm_budget_bytes"]
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
        result["hlo_path"] = save_hlo
    if verbose:
        m = result["memory"]["total_bytes_per_device"] / 2 ** 30
        r = roof
        print(f"[{arch} × {shape_name} × {'2pod' if multi_pod else '1pod'}] "
              f"OK mem={m:.2f}GiB/dev fits={result['fits_hbm']} "
              f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}",
              flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch × shape) cells on BOTH meshes")
    ap.add_argument("--level", type=int, default=2, help="GWT level")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    results = []

    def flush():
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    if args.all:
        for arch in configs.ARCH_IDS:
            for shape in configs.SHAPES:
                for mp in (False, True):
                    r = run_cell(arch, shape, multi_pod=mp,
                                 gwt_level=args.level)
                    if r["status"] != "ok":
                        print(f"[{arch} × {shape} × "
                              f"{'2pod' if mp else '1pod'}] "
                              f"{r['status'].upper()}: "
                              f"{r.get('reason') or r.get('error')}",
                              flush=True)
                    results.append(r)
                    flush()  # incremental: survive a mid-run crash
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        results.append(run_cell(args.arch, args.shape,
                                multi_pod=args.multipod,
                                gwt_level=args.level))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_bad = sum(r["status"] == "error" for r in results)
    print(f"{len(results)} cells: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skip' for r in results)} skip, {n_bad} error")
    sys.exit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
