"""Sharding rules + dry-run machinery.  Multi-device bits run in
subprocesses with their own XLA_FLAGS (the main process keeps 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=560):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, cwd=REPO, env=env,
                          timeout=timeout)


def test_spec_rules_divisibility_fallbacks():
    code = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shr
    from repro.models.layers import Axes
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    rules = shr.train_rules(mesh)
    # kv_heads=8 on 16-way model axis -> replicated
    s = shr.spec_for((2048, 8 * 128), Axes(("embed", "kv_heads")), mesh, rules)
    assert s == P("data", "model"), s  # 1024 % 16 == 0 -> fine
    s = shr.spec_for((2048, 2 * 128), Axes(("embed", "kv_heads")), mesh, rules)
    assert s == P("data", "model"), s
    s = shr.spec_for((2048, 8), Axes(("embed", "kv_heads")), mesh, rules)
    assert s == P("data"), s            # 8 % 16 != 0 -> replicated
    # qwen2-moe: 60 experts % 16 != 0 -> EP falls back, TP-in-expert
    s = shr.spec_for((60, 2048, 1408), Axes(("expert", "embed", "expert_mlp")),
                     mesh, rules)
    assert s == P(None, "data", "model"), s
    # qwen3: 128 experts -> true EP; expert_mlp loses model (axis used)
    s = shr.spec_for((128, 2048, 768), Axes(("expert", "embed", "expert_mlp")),
                     mesh, rules)
    assert s == P("model", "data"), s
    # seamless vocab 256206 % 16 != 0 -> replicated vocab
    s = shr.spec_for((256206, 1024), Axes(("vocab", "embed")), mesh, rules)
    assert s == P(None, "data"), s
    # long-decode cache: batch=1 unshardable, seq takes model x data
    drules = shr.decode_rules(mesh)
    s = shr.spec_for((1, 524288, 8, 128), Axes(("batch", "seq", "kv_heads",
                                                None)), mesh, drules)
    assert s == P(None, ("model", "data")), s
    print("RULES_OK")
    """
    r = _run(code)
    assert "RULES_OK" in r.stdout, r.stdout + r.stderr


def test_multipod_mesh_shapes():
    code = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.launch.mesh import make_production_mesh
    m1 = make_production_mesh()
    assert m1.axis_names == ("data", "model") and m1.devices.size == 256
    m2 = make_production_mesh(multi_pod=True)
    assert m2.axis_names == ("pod", "data", "model")
    assert m2.devices.size == 512
    print("MESH_OK")
    """
    r = _run(code)
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.parametrize("arch,shape", [
    ("qwen2.5-3b", "train_4k"),
    ("xlstm-350m", "long_500k"),
    ("seamless-m4t-large-v2", "train_4k"),
    ("qwen3-moe-30b-a3b", "decode_32k"),
])
def test_dryrun_cell_compiles(arch, shape):
    """One representative cell per family compiles on the production mesh
    (the full 40-cell × 2-mesh matrix runs via launch.dryrun --all; results
    in results/dryrun_baseline.json)."""
    code = f"""
    from repro.launch.dryrun import run_cell
    r = run_cell({arch!r}, {shape!r}, verbose=False)
    assert r["status"] == "ok", r
    assert r["fits_hbm"], r["memory"]
    print("CELL_OK", r["roofline"]["bottleneck"])
    """
    r = _run(code)
    assert "CELL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_dryrun_results_file_if_present():
    """Validate the committed baseline results: every non-skip cell ok and
    fits HBM on both meshes."""
    path = os.path.join(REPO, "results", "dryrun_baseline.json")
    if not os.path.exists(path):
        pytest.skip("baseline dry-run results not generated yet")
    cells = json.load(open(path))
    assert len(cells) >= 40
    bad = [c for c in cells if c["status"] == "error"]
    assert not bad, [(c["arch"], c["shape"], c.get("error")) for c in bad]
    for c in cells:
        if c["status"] == "ok":
            assert c["fits_hbm"], (c["arch"], c["shape"], c["memory"])


def test_hlo_analyzer_scales_while_bodies():
    """The analyzer multiplies loop-body FLOPs by the trip count."""
    code = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, sys
    sys.path.insert(0, ".")
    from benchmarks import hlo_analysis
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    mod = hlo_analysis.HloModule(txt)
    flops = mod.dot_flops()
    expect = 7 * 2 * 32 * 128 * 128
    assert abs(flops - expect) / expect < 0.01, (flops, expect)
    print("ANALYZER_OK")
    """
    r = _run(code)
    assert "ANALYZER_OK" in r.stdout, r.stdout + r.stderr
