"""Sharding rules + dry-run machinery + wavelet-compressed DP reduction
properties.  Multi-device bits run in subprocesses with their own
XLA_FLAGS via the shared ``conftest.run_in_devices`` helper (the main
process keeps 1 device)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, run_in_devices, settings, st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_spec_rules_divisibility_fallbacks():
    code = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shr
    from repro.models.layers import Axes
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    rules = shr.train_rules(mesh)
    # kv_heads=8 on 16-way model axis -> replicated
    s = shr.spec_for((2048, 8 * 128), Axes(("embed", "kv_heads")), mesh, rules)
    assert s == P("data", "model"), s  # 1024 % 16 == 0 -> fine
    s = shr.spec_for((2048, 2 * 128), Axes(("embed", "kv_heads")), mesh, rules)
    assert s == P("data", "model"), s
    s = shr.spec_for((2048, 8), Axes(("embed", "kv_heads")), mesh, rules)
    assert s == P("data"), s            # 8 % 16 != 0 -> replicated
    # qwen2-moe: 60 experts % 16 != 0 -> EP falls back, TP-in-expert
    s = shr.spec_for((60, 2048, 1408), Axes(("expert", "embed", "expert_mlp")),
                     mesh, rules)
    assert s == P(None, "data", "model"), s
    # qwen3: 128 experts -> true EP; expert_mlp loses model (axis used)
    s = shr.spec_for((128, 2048, 768), Axes(("expert", "embed", "expert_mlp")),
                     mesh, rules)
    assert s == P("model", "data"), s
    # seamless vocab 256206 % 16 != 0 -> replicated vocab
    s = shr.spec_for((256206, 1024), Axes(("vocab", "embed")), mesh, rules)
    assert s == P(None, "data"), s
    # long-decode cache: batch=1 unshardable, seq takes model x data
    drules = shr.decode_rules(mesh)
    s = shr.spec_for((1, 524288, 8, 128), Axes(("batch", "seq", "kv_heads",
                                                None)), mesh, drules)
    assert s == P(None, ("model", "data")), s
    print("RULES_OK")
    """
    r = run_in_devices(512, code)
    assert "RULES_OK" in r.stdout, r.stdout + r.stderr


def test_spec_rules_skip_axes_absent_from_mesh():
    """A pure-DP mesh has no 'model' axis: rules that name it must fall
    through to replication instead of KeyError-ing — the sharded train
    path builds its FSDP layout on exactly such meshes."""
    code = """
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.distributed import sharding as shr
    from repro.models.layers import Axes
    mesh = compat.make_mesh((8,), ("data",))
    rules = shr.train_rules(mesh)
    s = shr.spec_for((256, 64), Axes(("vocab", "embed")), mesh, rules)
    assert s == P(None, "data"), s      # vocab wants 'model' -> replicated
    s = shr.spec_for((64, 128), Axes(("embed", "mlp")), mesh, rules)
    assert s == P("data"), s
    print("DPMESH_OK")
    """
    r = run_in_devices(8, code)
    assert "DPMESH_OK" in r.stdout, r.stdout + r.stderr


def test_multipod_mesh_shapes():
    code = """
    from repro.launch.mesh import make_production_mesh
    m1 = make_production_mesh()
    assert m1.axis_names == ("data", "model") and m1.devices.size == 256
    m2 = make_production_mesh(multi_pod=True)
    assert m2.axis_names == ("pod", "data", "model")
    assert m2.devices.size == 512
    print("MESH_OK")
    """
    r = run_in_devices(512, code)
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.parametrize("arch,shape", [
    ("qwen2.5-3b", "train_4k"),
    ("xlstm-350m", "long_500k"),
    ("seamless-m4t-large-v2", "train_4k"),
    ("qwen3-moe-30b-a3b", "decode_32k"),
])
def test_dryrun_cell_compiles(arch, shape):
    """One representative cell per family compiles on the production mesh
    (the full 40-cell × 2-mesh matrix runs via launch.dryrun --all; results
    in results/dryrun_baseline.json)."""
    code = f"""
    from repro.launch.dryrun import run_cell
    r = run_cell({arch!r}, {shape!r}, verbose=False)
    assert r["status"] == "ok", r
    assert r["fits_hbm"], r["memory"]
    print("CELL_OK", r["roofline"]["bottleneck"])
    """
    # dryrun sets its own 512-device XLA_FLAGS before backend init
    r = run_in_devices(1, code)
    assert "CELL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_dryrun_results_file_if_present():
    """Validate the committed baseline results: every non-skip cell ok and
    fits HBM on both meshes."""
    path = os.path.join(REPO, "results", "dryrun_baseline.json")
    if not os.path.exists(path):
        pytest.skip("baseline dry-run results not generated yet")
    cells = json.load(open(path))
    assert len(cells) >= 40
    bad = [c for c in cells if c["status"] == "error"]
    assert not bad, [(c["arch"], c["shape"], c.get("error")) for c in bad]
    for c in cells:
        if c["status"] == "ok":
            assert c["fits_hbm"], (c["arch"], c["shape"], c["memory"])


def test_hlo_analyzer_scales_while_bodies():
    """The analyzer multiplies loop-body FLOPs by the trip count."""
    code = """
    import jax, jax.numpy as jnp, sys
    sys.path.insert(0, ".")
    from benchmarks import hlo_analysis
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    mod = hlo_analysis.HloModule(txt)
    flops = mod.dot_flops()
    expect = 7 * 2 * 32 * 128 * 128
    assert abs(flops - expect) / expect < 0.01, (flops, expect)
    print("ANALYZER_OK")
    """
    r = run_in_devices(8, code)
    assert "ANALYZER_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# Property tests: wavelet-compressed DP reduction (satellite).
#
# The pure per-shard math (compression.reduce_terms / reconstruct) runs in
# THIS process against compression.emulated_mean — a sequential worker-order
# sum whose bitwise agreement with the real 8-device psum is pinned
# separately in tests/test_sharded_train.py — so the properties get full
# hypothesis coverage without paying a subprocess per draw.
# ---------------------------------------------------------------------------

def _stack(seed: int, n_workers: int, m: int, n: int, scale: float = 1.0):
    return jax.random.normal(jax.random.key(seed),
                             (n_workers, m, n), jnp.float32) * scale


def _exact_mean(stack):
    from repro.distributed import compression
    return compression.emulated_mean(stack, level=0, detail_dtype=None)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(0, 1000), st.floats(0.1, 8.0))
def test_compressed_mean_linearity_exact_without_quantization(level, seed,
                                                              scale):
    """mean ∘ DWT == DWT ∘ mean: with f32 detail bands (no quantization)
    the compressed reduction IS the exact mean up to f32 rounding of the
    orthonormal round-trip — the linearity the whole scheme rests on."""
    from repro.distributed import compression
    g = _stack(seed, 4, 3, 16 << level, scale)
    out = compression.emulated_mean(g, level=level, detail_dtype=jnp.float32)
    exact = _exact_mean(g)
    tol = 1e-6 * float(jnp.max(jnp.abs(exact)) + 1e-20)
    assert float(jnp.max(jnp.abs(out - exact))) <= tol


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(0, 1000))
def test_compressed_mean_error_bounded_by_detail_eps(level, seed):
    """Detail-band quantization is the ONLY error source, so the deviation
    from the exact mean is bounded by the detail dtype's machine epsilon
    times the gradient magnitude (loose constant for the transform's ~√2
    band growth and the accumulation), and tightens with the wire dtype:
    err(bf16) ≤ err(f8) bound-wise."""
    from repro.distributed import compression
    g = _stack(seed, 8, 4, 8 << level)
    exact = _exact_mean(g)
    gmax = float(jnp.max(jnp.abs(g)))
    for dtype in (jnp.bfloat16, jnp.float8_e4m3fn):
        out = compression.emulated_mean(g, level=level, detail_dtype=dtype)
        err = float(jnp.max(jnp.abs(out - exact)))
        bound = 8.0 * float(jnp.finfo(dtype).eps) * gmax
        assert err <= bound, (str(dtype), err, bound)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_non_compressible_leaves_take_exact_psum(seed):
    """The ndim<2 / non-divisible-width / level-0 fallbacks return the
    exact psum mean bitwise (no wavelet machinery touches them)."""
    from repro.distributed import compression
    key = jax.random.key(seed)
    vec = jax.random.normal(key, (8, 33))                      # ndim < 2
    odd = jax.random.normal(key, (8, 4, 30))       # 30 % 4 != 0 at level 2
    wide = jax.random.normal(key, (8, 4, 32))
    for stack, level, dtype in [(vec, 2, jnp.bfloat16),
                                (odd, 2, jnp.bfloat16),
                                (wide, 0, jnp.bfloat16),
                                (wide, 2, None)]:              # exact mode
        out = compression.emulated_mean(stack, level=level, detail_dtype=dtype)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(_exact_mean(stack)))


def test_compressible_predicate():
    from repro.distributed.compression import compressible
    assert compressible((4, 32), 2)
    assert not compressible((32,), 2)       # 1-D
    assert not compressible((4, 30), 2)     # width ∤ 2^level
    assert not compressible((4, 32), 0)     # level 0


def test_dp_reduce_spec_parse():
    from repro.distributed.compression import DPReduceSpec
    assert DPReduceSpec.parse("none") is None
    ex = DPReduceSpec.parse("exact", level=3)
    assert ex.exact and ex.detail_dtype is None
    co = DPReduceSpec.parse("compressed", level=2,
                            detail_dtype="float8_e4m3fn")
    assert not co.exact
    assert jnp.dtype(co.detail_dtype) == jnp.dtype("float8_e4m3fn")
    with pytest.raises(ValueError):
        DPReduceSpec.parse("zstd")


def test_tree_wire_bytes_accounting():
    """Per-leaf accounting: compressible leaves charge the split format,
    fallback leaves full f32; the f8 wire at level 2 clears the ≥2×
    headline the shard benchmark gates on."""
    from repro.distributed.compression import DPReduceSpec, tree_wire_bytes
    tree = {"w": jax.ShapeDtypeStruct((64, 256), jnp.float32),
            "b": jax.ShapeDtypeStruct((256,), jnp.float32)}
    full = tree_wire_bytes(tree, None)
    assert full == 2 * (64 * 256 + 256) * 4
    bf16 = tree_wire_bytes(tree, DPReduceSpec(level=2))
    f8 = tree_wire_bytes(
        tree, DPReduceSpec(level=2, detail_dtype=jnp.float8_e4m3fn))
    w = 64 * 256
    assert bf16 == 2 * ((w // 4) * 4 + (3 * w // 4) * 2) + 2 * 256 * 4
    assert f8 == 2 * ((w // 4) * 4 + (3 * w // 4) * 1) + 2 * 256 * 4
    assert f8 < bf16 < full
    # the vector rides the exact psum in every mode
    only_w = {"w": tree["w"]}
    assert tree_wire_bytes(only_w, None) / tree_wire_bytes(
        only_w, DPReduceSpec(level=2, detail_dtype=jnp.float8_e4m3fn)) >= 2.0
