"""Test fixtures.  NOTE: no XLA_FLAGS here — unit tests must see the real
single CPU device; multi-device tests spawn subprocesses with their own
XLA_FLAGS (see test_distributed.py)."""

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
