"""Test fixtures + shared multi-device / property-test machinery.

NOTE: no XLA_FLAGS here — unit tests must see the real single CPU device;
multi-device tests run their code in subprocesses via
:func:`run_in_devices`, which owns the ``XLA_FLAGS`` fake-device request
(previously copy-pasted per test file).

Backend-sweep tier (ROADMAP multi-backend item): the ``kernel_impl``
fixture parametrizes kernel/engine equivalence tests over
``impl ∈ {jnp, interpret}``.  The ``interpret`` leg (Pallas interpreter —
slow on CPU) carries the ``slow`` marker and is skipped by default so
tier-1 stays fast; run it with ``pytest --runslow`` (``pallas`` itself
needs TPU hardware and is covered by the same entry points via
``REPRO_KERNEL_IMPL`` once available).
"""

import itertools
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def device_env(n: int, **extra) -> dict:
    """Subprocess environment seeing ``n`` simulated host-platform CPU
    devices: ``PYTHONPATH=src``, CPU platform pinned, inherited
    ``XLA_FLAGS`` dropped (the fake-device request must be THIS process's
    choice, not leakage).  The single shared recipe behind
    :func:`run_in_devices` and the launcher-driving tests."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    if n > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env.update(extra)
    return env


def run_in_devices(n: int, code: str, timeout: int = 560, env=None):
    """Run ``code`` in a subprocess that sees ``n`` simulated host-platform
    CPU devices (its own ``XLA_FLAGS``; the calling test process keeps its
    single real device).  ``code`` is dedented; cwd is the repo root with
    ``PYTHONPATH=src``.  Returns the ``CompletedProcess`` — asserting on
    a sentinel in ``r.stdout`` is the caller's job (include
    ``r.stdout + r.stderr`` in the assert message for debuggability)."""
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, cwd=REPO,
                          env=device_env(n, **(env or {})), timeout=timeout)


# ---------------------------------------------------------------------------
# hypothesis with a deterministic fallback: property tests run everywhere,
# with full random draws where hypothesis is installed (requirements-dev)
# and a fixed sample grid (endpoints + midpoint per strategy) without it.
# Import as ``from conftest import given, settings, st``.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    class _IntRange:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def samples(self):
            return sorted({self.lo, (self.lo + self.hi) // 2, self.hi})

    class _FloatRange(_IntRange):
        def samples(self):
            return [self.lo, (self.lo + self.hi) / 2.0, self.hi]

    class st:  # noqa: N801 - mimics hypothesis.strategies
        integers = staticmethod(_IntRange)
        floats = staticmethod(_FloatRange)

    def settings(**_kw):
        return lambda f: f

    def given(*strategies):
        def deco(f):
            def wrapper():
                for args in itertools.product(
                        *(s.samples() for s in strategies)):
                    f(*args)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked slow (backend-sweep tier)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: backend-sweep / long-running tier (needs --runslow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow tier: use --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


@pytest.fixture(params=["jnp",
                        pytest.param("interpret", marks=pytest.mark.slow)])
def kernel_impl(request):
    """Fused-kernel backend under test (jnp fast tier; interpret slow)."""
    return request.param
