"""Test fixtures.  NOTE: no XLA_FLAGS here — unit tests must see the real
single CPU device; multi-device tests spawn subprocesses with their own
XLA_FLAGS (see test_distributed.py).

Backend-sweep tier (ROADMAP multi-backend item): the ``kernel_impl``
fixture parametrizes kernel/engine equivalence tests over
``impl ∈ {jnp, interpret}``.  The ``interpret`` leg (Pallas interpreter —
slow on CPU) carries the ``slow`` marker and is skipped by default so
tier-1 stays fast; run it with ``pytest --runslow`` (``pallas`` itself
needs TPU hardware and is covered by the same entry points via
``REPRO_KERNEL_IMPL`` once available).
"""

import jax
import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked slow (backend-sweep tier)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: backend-sweep / long-running tier (needs --runslow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow tier: use --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


@pytest.fixture(params=["jnp",
                        pytest.param("interpret", marks=pytest.mark.slow)])
def kernel_impl(request):
    """Fused-kernel backend under test (jnp fast tier; interpret slow)."""
    return request.param
