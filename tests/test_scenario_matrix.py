"""Substrate × optimizer-family × codec conformance matrix.

One engine, every scenario: for each substrate {llama, moe, ssm, xlstm,
encdec} × family {gwt2, adam, galore, apollo, adarankgrad, rso} × codec
{f32, int8}, one real-gradient update must agree between the bucketed
(lax.scan) and unrolled per-leaf engines, and a checkpoint save/restore
mid-run must continue bitwise-identically to the uninterrupted run — the
state contract every SIGTERM resume depends on.

A representative subset (each substrate and each family at least once,
both codecs) runs in tier-1; the full 60-cell product runs behind
``--runslow``.  Gradients are REAL (``jax.grad`` of each substrate's
``loss_fn`` on synthetic batches), so per-arch leaf plans — MoE experts,
SSM recurrent leaves, xLSTM gate kernels, enc-dec cross-attention — are
exercised, not simulated.

Also here: the build-time validation regression (satellite: an
unsupported (rule, leaf) pairing must fail at plan time with the leaf
path in the error, not at scan trace time) and the recurrent-leaf
routing policy.
"""

import functools
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.checkpoint.manager import CheckpointManager
from repro.models import encdec, lm
from repro.optim import engine
from repro.optim.base import default_eligible

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------------------
# Substrates: each smoke config shrunk to the smallest shape that still
# contains every leaf kind (experts + router, mamba recurrences, both
# xLSTM cell types, enc+dec+cross attention).
# ---------------------------------------------------------------------------

SUBSTRATE_ARCH = {
    "llama": ("llama-60m",
              dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64, vocab=64)),
    "moe": ("qwen2-moe-a2.7b",
            dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                 head_dim=16, d_ff_expert=32, vocab=64)),
    "ssm": ("jamba-v0.1-52b",
            dict(n_layers=2, pattern=("mamba", "attn+moe"), d_model=32,
                 n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                 d_ff_expert=32, vocab=64)),
    "xlstm": ("xlstm-350m",
              dict(n_layers=2, pattern=("mlstm", "slstm"), d_model=32,
                   n_heads=2, head_dim=16, vocab=64)),
    "encdec": ("seamless-m4t-large-v2",
               dict(n_layers=2, n_enc_layers=1, n_dec_layers=1, d_model=32,
                    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                    vocab=64)),
}

FAMILIES = {
    "gwt2": lambda codec, bucketed: optim.make(
        "gwt", lr=0.01, level=2, state_codec=codec, bucketed=bucketed),
    "adam": lambda codec, bucketed: optim.make(
        "adam", lr=0.01, state_codec=codec, bucketed=bucketed),
    "galore": lambda codec, bucketed: optim.make(
        "galore", lr=0.01, rank=4, update_gap=2, state_codec=codec,
        bucketed=bucketed),
    "apollo": lambda codec, bucketed: optim.make(
        "apollo", lr=0.01, rank=4, update_gap=2, state_codec=codec,
        bucketed=bucketed),
    "adarankgrad": lambda codec, bucketed: optim.make(
        "adarankgrad", lr=0.01, rank=4, update_gap=2, state_codec=codec,
        bucketed=bucketed),
    "rso": lambda codec, bucketed: optim.make(
        "rso", lr=0.01, rank=4, update_gap=2, state_codec=codec,
        bucketed=bucketed),
}


@functools.lru_cache(maxsize=None)
def _substrate(name):
    """(mod, cfg, params, grads_step1, grads_step2) with REAL gradients."""
    arch, kw = SUBSTRATE_ARCH[name]
    cfg = configs.get_smoke(arch).with_(**kw)
    mod = encdec if cfg.arch_class == "encdec" else lm
    params = mod.init(cfg, jax.random.key(0))
    B, S = 2, 16

    def batch(seed):
        toks = jax.random.randint(jax.random.key(100 + seed), (B, S), 0,
                                  cfg.vocab)
        b = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        if cfg.arch_class == "encdec":
            b["enc_embeds"] = 0.1 * jax.random.normal(
                jax.random.key(200 + seed), (B, S // 4, cfg.d_model),
                jnp.float32)
        return b

    gfn = jax.jit(jax.grad(lambda p, b: mod.loss_fn(cfg, p, b)))
    return mod, cfg, params, gfn(params, batch(0)), gfn(params, batch(1))


def _assert_tree_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def _assert_tree_close(a, b, msg=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   atol=1e-6, rtol=1e-6, err_msg=msg)


# tier-1 subset: every substrate once, every family once, both codecs.
_TIER1 = {("llama", "adarankgrad", "f32"), ("llama", "rso", "int8"),
          ("moe", "gwt2", "f32"), ("ssm", "adam", "int8"),
          ("xlstm", "apollo", "f32"), ("encdec", "galore", "f32")}

CELLS = [pytest.param(s, f, c,
                      marks=() if (s, f, c) in _TIER1
                      else (pytest.mark.slow,),
                      id=f"{s}-{f}-{c}")
         for s in SUBSTRATE_ARCH for f in FAMILIES for c in ("f32", "int8")]


@pytest.mark.parametrize("substrate,family,codec", CELLS)
def test_matrix_cell(substrate, family, codec, tmp_path):
    mod, cfg, params, g1, g2 = _substrate(substrate)
    make = FAMILIES[family]

    # -- bucketed ≡ unrolled on one real-gradient update -------------------
    ob, ou = make(codec, True), make(codec, False)
    pb1, sb1 = jax.jit(ob.update)(g1, ob.init(params), params)
    pu1, su1 = jax.jit(ou.update)(g1, ou.init(params), params)
    if family == "gwt2":
        # XLA fuses the Haar butterfly differently inside the scan body:
        # tolerance, not bitwise (same policy as test_engine).
        _assert_tree_close(pu1, pb1, f"{substrate}/{family}/{codec} params")
    else:
        _assert_tree_equal(pu1, pb1, f"{substrate}/{family}/{codec} params")
        _assert_tree_equal(su1, sb1, f"{substrate}/{family}/{codec} state")

    # -- resume bitwise: save/restore mid-run, continue == continuous ------
    pb2, sb2 = jax.jit(ob.update)(g2, sb1, pb1)
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"params": pb1, "opt": sb1}, blocking=True)
    restored, step = cm.restore(None, {"params": pb1, "opt": sb1})
    assert step == 1
    pr2, sr2 = jax.jit(ob.update)(g2, restored["opt"], restored["params"])
    _assert_tree_equal(pr2, pb2, f"{substrate}/{family}/{codec} resume p")
    _assert_tree_equal(sr2, sb2, f"{substrate}/{family}/{codec} resume s")


# ---------------------------------------------------------------------------
# Build-time validation (satellite): unsupported (rule, leaf) pairings die
# at plan time, naming the leaf — regression for the pre-fix behaviour of
# erroring deep inside the scan trace.
# ---------------------------------------------------------------------------

def test_unsupported_rule_leaf_fails_at_build_with_path():
    gopt = optim.make("gwt", lr=0.01, level=2)
    # the public API never produces this pairing (_leaf_mode falls back to
    # plain on non-divisibility), so extract the real wavelet rule and
    # force it onto an ssm recurrent leaf with non-divisible axes.
    rule = gopt.engine.assign("layers/b0/mixer/wq",
                              jax.ShapeDtypeStruct((8, 16), jnp.float32))
    assert rule.kind == "gwt_last"
    forced = engine.build(lambda p, l: rule)
    bad = {"mixer": {"a_log": jnp.ones((6, 17), jnp.float32)}}
    with pytest.raises(ValueError, match=r"mixer/a_log"):
        forced.init(bad)
    # the same failure (memoization off-path) at update/plan time too
    with pytest.raises(ValueError, match=r"mixer/a_log"):
        forced.engine.plan(bad)


def test_validation_memoizes_per_signature():
    opt = optim.make("adam", lr=0.01)
    params = {"w": jnp.ones((4, 4))}
    opt.engine.plan(params)
    n = len(opt.engine._validated)
    assert n >= 1
    opt.engine.plan(params)  # same signature: no new probes
    assert len(opt.engine._validated) == n


# ---------------------------------------------------------------------------
# Recurrent-leaf routing policy: SSM/xLSTM recurrence kernels route around
# subspace compression (plain Adam), attention/MLP projections do not.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path,shape,eligible", [
    ("layers/b0/mixer/x_proj", (32, 20), False),
    ("layers/b0/mixer/dt_proj", (4, 32), False),
    ("layers/b0/mixer/w_igate", (32, 2), False),
    ("layers/b0/mixer/w_fgate", (32, 2), False),
    ("layers/b0/cell/r", (2, 16, 64), False),
    ("layers/b0/mixer/wq", (32, 32), True),
    ("layers/b0/ffn/w_gate", (32, 64), True),  # 'gate' != 'igate'/'fgate'
    ("layers/b0/moe/w_up", (4, 32, 64), True),
])
def test_recurrent_leaf_eligibility(path, shape, eligible):
    leaf = jax.ShapeDtypeStruct(shape, jnp.float32)
    assert default_eligible(path, leaf) is eligible


@pytest.mark.parametrize("substrate", ["ssm", "xlstm"])
def test_recurrent_leaves_get_plain_rule_end_to_end(substrate):
    """Through the public gwt API on real substrate params: every denied
    recurrent leaf lands in a plain bucket, and at least one compressed
    (wavelet) bucket exists — the policy narrows, it doesn't blank out."""
    _, cfg, params, _, _ = _substrate(substrate)
    opt = optim.make("gwt", lr=0.01, level=2)
    plan = opt.engine.plan(params)
    kinds = {}
    for b in plan.buckets:
        for p in b.paths:
            kinds[p] = b.rule.kind
    denied = [p for p in kinds
              if any(s in p for s in ("x_proj", "dt_proj", "igate", "fgate"))
              or p.rsplit("/", 1)[-1] == "r"]
    assert denied, f"no recurrent leaves found in {substrate} params"
    for p in denied:
        assert kinds[p] == "plain", f"{p} routed to {kinds[p]}"
    assert any(k.startswith("gwt_") for k in kinds.values())


# ---------------------------------------------------------------------------
# Launcher-level SIGTERM + --resume on a non-llama substrate (slow tier):
# the matrix cells pin the engine-state contract; this pins the whole
# process path (TrainLoop chunk grid, data realignment) for xlstm.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sigterm_resume_substrate_xlstm_bitwise(tmp_path):
    def launch(ckpt_dir, wait=True, resume=False):
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", "xlstm-350m", "--smoke", "--optimizer", "gwt",
               "--level", "2", "--lr", "0.01", "--steps", "24",
               "--batch", "2", "--seq", "32", "--log-every", "4",
               "--ckpt-every", "8", "--ckpt-dir", str(ckpt_dir)] \
            + (["--resume"] if resume else [])
        env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
        proc = subprocess.Popen(cmd, cwd=REPO, env=env,
                                stdout=subprocess.PIPE,
                                stderr=subprocess.PIPE, text=True)
        if not wait:
            return proc
        out, err = proc.communicate(timeout=600)
        assert proc.returncode == 0, out + err
        return out + err

    def final_leaves(ckpt_dir):
        d = os.path.join(str(ckpt_dir), "step_000000024")
        assert os.path.exists(os.path.join(d, "COMMITTED"))
        return {n: open(os.path.join(d, n), "rb").read()
                for n in sorted(os.listdir(d)) if n.endswith(".bin")}

    a, b = tmp_path / "interrupted", tmp_path / "straight"
    proc = launch(a, wait=False)
    first = os.path.join(str(a), "step_000000008", "COMMITTED")
    deadline = time.time() + 570
    while time.time() < deadline and proc.poll() is None \
            and not os.path.exists(first):
        time.sleep(0.05)
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
    out, err = proc.communicate(timeout=120)
    assert proc.returncode == 0, out + err
    launch(a, resume=True)
    launch(b)
    la, lb = final_leaves(a), final_leaves(b)
    assert la.keys() == lb.keys()
    for name in la:
        assert la[name] == lb[name], f"leaf {name} differs after resume"
