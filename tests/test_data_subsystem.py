"""Streaming tokenized-corpus data subsystem: corpus round-trip
(build -> mmap read -> detokenize), pure sample-order determinism (incl.
across processes), process-worker ≡ thread-Prefetcher bitwise equality,
prefetcher failure modes, the eval harness, and the DP error-feedback
bias property."""

import hashlib
import os

import numpy as np
import pytest

from conftest import run_in_devices
from repro.data import build_corpus
from repro.data.order import SampleOrder
from repro.data.pipeline import (CorpusLM, Prefetcher, TokenizingTextLM,
                                 make_source)
from repro.data.store import TokenStore
from repro.data.tokenizer import BPETokenizer, ByteTokenizer
from repro.data.workers import ProcessPrefetcher

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURE_GLOB = os.path.join(REPO, "tests", "fixtures", "corpus", "*.txt")


@pytest.fixture(scope="session")
def corpus_dir(tmp_path_factory):
    """The committed fixture corpus, built once per session (BPE-512)."""
    out = tmp_path_factory.mktemp("corpus")
    build_corpus.build(FIXTURE_GLOB, str(out), tokenizer_kind="bpe",
                       vocab_size=512, eval_fraction=0.05)
    return str(out)


# ---------------------------------------------------------------------------
# Tokenizers
# ---------------------------------------------------------------------------

def test_bpe_roundtrip_and_determinism():
    docs = build_corpus.read_documents(FIXTURE_GLOB)
    tok = BPETokenizer.train(docs, vocab_size=384)
    assert tok.vocab_size == 384
    text = build_corpus.DOC_SEP.join(docs)
    ids = tok.encode(text)
    assert tok.decode(ids) == text
    # merges actually compress vs bytes
    assert len(ids) < 0.6 * len(text.encode("utf-8"))
    # training is deterministic, and the json round-trip is exact
    tok2 = BPETokenizer.train(docs, vocab_size=384)
    assert tok.merges == tok2.merges
    tok3 = BPETokenizer.from_json(tok.to_json())
    np.testing.assert_array_equal(ids, tok3.encode(text))
    assert tok.config_hash() == tok3.config_hash()


def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer()
    text = "wavelet subspaces, compact optimizer states\n"
    assert tok.decode(tok.encode(text)) == text


def test_tokenizing_text_source_deterministic():
    """The on-the-fly BPE source (the process-worker benchmark workload)
    honors the batch(i)-pure-in-i contract like every other source."""
    docs = build_corpus.read_documents(FIXTURE_GLOB)
    tok = BPETokenizer.train(docs, vocab_size=300)
    text = build_corpus.DOC_SEP.join(docs)
    a = TokenizingTextLM(text, tok, 16, 4, seed=2)
    b = TokenizingTextLM(text, tok, 16, 4, seed=2)
    for i in (0, 5):
        np.testing.assert_array_equal(a.batch(i)["tokens"],
                                      b.batch(i)["tokens"])
    batch = a.batch(0)
    assert batch["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])


# ---------------------------------------------------------------------------
# Corpus store round-trip
# ---------------------------------------------------------------------------

def test_corpus_roundtrip_and_hash(corpus_dir):
    st = TokenStore(corpus_dir)
    assert st.verify_hash()
    text = build_corpus.DOC_SEP.join(
        build_corpus.read_documents(FIXTURE_GLOB))
    toks = np.concatenate([st.split("train").tokens(),
                           st.split("eval").tokens()])
    assert st.tokenizer.decode(toks) == text
    # eval split is a non-empty held-out tail
    assert st.split("eval").n_tokens > 0
    assert st.split("train").n_tokens > 10 * st.split("eval").n_tokens


def test_window_map_multi_shard(tmp_path):
    """Windows never cross shard boundaries and window(i) returns exactly
    the shard-local slice, across a forced multi-shard layout."""
    tok = ByteTokenizer()
    stream = np.arange(1000) % 251
    from repro.data.store import write_corpus
    write_corpus(str(tmp_path), stream.astype(np.uint16), tok,
                 shard_tokens=137, eval_fraction=0.0)
    st = TokenStore(str(tmp_path))
    view = st.split("train")
    S = 16
    counts = [max(c - 1, 0) // S
              for c in (s["n_tokens"] for s in view.shards)]
    assert view.n_windows(S) == sum(counts) > 1
    # reconstruct each window by hand from the flat stream + shard table
    base = 0
    wi = 0
    for s, cnt in zip(view.shards, counts):
        for local in range(cnt):
            want = stream[base + local * S: base + local * S + S + 1]
            np.testing.assert_array_equal(view.window(wi, S), want)
            wi += 1
        base += s["n_tokens"]
    with pytest.raises(IndexError):
        view.window(view.n_windows(S), S)


# ---------------------------------------------------------------------------
# Sample order: permutation per epoch, pure across processes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,seed", [(1, 0), (7, 3), (180, 0), (1000, 42)])
def test_order_is_permutation_every_epoch(n, seed):
    o = SampleOrder(n, seed)
    for epoch in (0, 1, 3):
        w = o.windows(np.arange(n, dtype=np.int64) + epoch * n)
        assert sorted(w.tolist()) == list(range(n))
    if n > 10:
        w0 = o.windows(np.arange(n))
        w1 = o.windows(np.arange(n) + n)
        assert (w0 != w1).mean() > 0.9          # epochs reshuffle
        assert (w0 != SampleOrder(n, seed + 1).windows(np.arange(n))) \
            .mean() > 0.9                        # seeds differ


def test_order_deterministic_across_processes():
    o = SampleOrder(997, seed=13)
    here = hashlib.sha256(o.windows(np.arange(4000)).tobytes()).hexdigest()
    r = run_in_devices(1, """
        import hashlib, numpy as np
        from repro.data.order import SampleOrder
        o = SampleOrder(997, seed=13)
        d = hashlib.sha256(o.windows(np.arange(4000)).tobytes()).hexdigest()
        print("DIGEST", d)
    """)
    assert f"DIGEST {here}" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# CorpusLM source: determinism, DP slicing, vocab guard
# ---------------------------------------------------------------------------

def test_corpuslm_batches_deterministic(corpus_dir):
    a = CorpusLM(corpus_dir, 32, 8, seed=5)
    b = CorpusLM(corpus_dir, 32, 8, seed=5)
    for i in (0, 7, 1000):
        x, y = a.batch(i), b.batch(i)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])
        np.testing.assert_array_equal(x["tokens"][:, 1:], x["labels"][:, :-1])


def test_corpuslm_dp_slices_compose(corpus_dir):
    full = CorpusLM(corpus_dir, 32, 8, seed=0).batch(3)
    for H in (2, 4):
        parts = [CorpusLM(corpus_dir, 32, 8, seed=0, dp_rank=r,
                          dp_size=H).batch(3) for r in range(H)]
        np.testing.assert_array_equal(
            np.concatenate([p["tokens"] for p in parts]), full["tokens"])


def test_corpuslm_eval_split_sequential(corpus_dir):
    ev = CorpusLM(corpus_dir, 32, 4, seed=0, split="eval")
    assert ev.order is None          # fixed order: comparable eval points
    b0a, b0b = ev.batch(0), ev.batch(0)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])


def test_make_source_corpus_vocab_guard(corpus_dir):
    with pytest.raises(ValueError, match="exceeds model vocab"):
        make_source("corpus", 256, 32, 4, corpus_dir=corpus_dir)
    src = make_source("corpus", 512, 32, 4, corpus_dir=corpus_dir)
    assert src.batch(0)["tokens"].shape == (4, 32)


# ---------------------------------------------------------------------------
# Satellite: thread-Prefetcher failure modes (error propagation + close)
# ---------------------------------------------------------------------------

class _FailsAt:
    batch_size = 2

    def __init__(self, fail_at=3):
        self.fail_at = fail_at

    def batch(self, i):
        if i == self.fail_at:
            raise ValueError(f"boom at {i}")
        return {"x": np.full((2, 4), i, np.int32)}


def test_prefetcher_reraises_source_error_in_next():
    pf = Prefetcher(_FailsAt(3), depth=2)
    got = []
    with pytest.raises(ValueError, match="boom at 3"):
        for _ in range(10):
            got.append(next(pf)[0])
    assert got == [0, 1, 2]          # batches before the failure drain
    with pytest.raises(ValueError):  # re-raises, never hangs
        next(pf)
    pf.close()


def test_prefetcher_close_joins_thread():
    pf = Prefetcher(_FailsAt(10**9), depth=1)
    next(pf)
    pf.close()
    assert not pf._thread.is_alive()


# ---------------------------------------------------------------------------
# Process workers: bitwise equality with the thread path, worker-count
# invariance, error propagation
# ---------------------------------------------------------------------------

def _stream(pf, n):
    out = []
    for _ in range(n):
        i, b = next(pf)
        out.append((i, {k: np.asarray(v) for k, v in b.items()}))
    return out


def test_process_prefetcher_bitwise_equals_thread(corpus_dir):
    src = CorpusLM(corpus_dir, 32, 4, seed=1)
    with Prefetcher(src, start_step=7, depth=4) as pf:
        want = _stream(pf, 6)
    with ProcessPrefetcher(src, start_step=7, depth=4, num_workers=2) as pp:
        got = _stream(pp, 6)
    assert [i for i, _ in got] == [i for i, _ in want] == list(range(7, 13))
    for (_, a), (_, b) in zip(want, got):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_process_prefetcher_worker_count_invariance(corpus_dir):
    src = CorpusLM(corpus_dir, 32, 4, seed=1)
    with ProcessPrefetcher(src, start_step=0, depth=4, num_workers=1) as p1:
        s1 = _stream(p1, 5)
    with ProcessPrefetcher(src, start_step=0, depth=6, num_workers=3) as p3:
        s3 = _stream(p3, 5)
    for (i1, a), (i3, b) in zip(s1, s3):
        assert i1 == i3
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_process_prefetcher_propagates_worker_error():
    with ProcessPrefetcher(_FailsAt(2), depth=4, num_workers=2) as pp:
        got = []
        with pytest.raises(ValueError, match="boom at 2"):
            for _ in range(8):
                got.append(next(pp)[0])
        assert got == [0, 1]


# ---------------------------------------------------------------------------
# Eval harness
# ---------------------------------------------------------------------------

def test_evaluator_streaming_and_trainloop_grid(corpus_dir):
    import jax
    from repro import configs, optim
    from repro.data.eval import make_lm_evaluator
    from repro.models import lm
    from repro.runtime.fault_tolerance import TrainLoop

    cfg = configs.get_smoke("llama-60m").with_(vocab=512)
    opt = optim.make("adam", lr=1e-2)
    params = lm.init(cfg, jax.random.key(0))
    st = opt.init(params)
    train_src = CorpusLM(corpus_dir, 32, 4, seed=0)
    ev = make_lm_evaluator(
        cfg, lm, CorpusLM(corpus_dir, 32, 4, seed=0, split="eval"),
        n_batches=2)
    r0 = ev(params)                       # pure read: params untouched
    assert np.isfinite(r0["loss"]) and r0["ppl"] > 1

    loop = TrainLoop(lm.make_train_step(cfg, opt), None, train_src,
                     log_every=4, max_chunk=4, log=lambda s: None,
                     evaluator=ev, eval_every=6)
    # the loop donates its inputs: hand it copies, keep the originals
    p2, s2, losses = loop.run(*jax.tree.map(lambda a: a.copy(),
                                            (params, st)), num_steps=12)
    # eval points land exactly on the absolute eval grid
    assert [s for s, _ in ev.history] == [6, 12]
    assert ev.history[-1][1] < r0["loss"]  # it learned something
    # evaluation did not perturb training: a no-eval run matches bitwise
    loop2 = TrainLoop(lm.make_train_step(cfg, opt), None,
                      CorpusLM(corpus_dir, 32, 4, seed=0),
                      log_every=4, max_chunk=4, log=lambda s: None,
                      evaluator=ev, eval_every=6)
    p3, s3, losses3 = loop2.run(*jax.tree.map(lambda a: a.copy(),
                                              (params, st)), num_steps=12)
    np.testing.assert_array_equal(np.asarray(losses), np.asarray(losses3))
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Satellite: DP error feedback — compensated mean's bias shrinks
# ---------------------------------------------------------------------------

def test_error_feedback_bias_shrinks_over_rounds():
    import jax
    import jax.numpy as jnp
    from repro.distributed import compression as C

    n, shape, level = 4, (8, 32), 2
    gs = jax.random.normal(jax.random.key(0), (n,) + shape, jnp.float32)
    true = np.asarray(gs.mean(0), np.float64)
    dd = jnp.float8_e4m3fn           # coarse details -> visible bias

    plain = np.asarray(C.emulated_mean(gs, level, dd), np.float64)
    bias_plain = np.abs(plain - true).mean()
    assert bias_plain > 0            # quantization really biases the mean

    err = jnp.zeros_like(gs)
    acc = np.zeros(shape, np.float64)
    T = 8
    for _ in range(T):
        r, err = C.emulated_mean_ef(gs, err, level, dd)
        acc += np.asarray(r, np.float64)
    bias_ef = np.abs(acc / T - true).mean()
    # the residue telescopes: time-averaged bias shrinks vs uncompensated
    assert bias_ef < 0.5 * bias_plain, (bias_ef, bias_plain)
    # round 1 with zero residue == the uncompensated reduction
    r1, e1 = C.emulated_mean_ef(gs, jnp.zeros_like(gs), level, dd)
    np.testing.assert_allclose(np.asarray(r1), plain, rtol=1e-6, atol=1e-7)
    assert float(jnp.abs(e1).max()) > 0   # a real residue accumulated


def test_error_feedback_sharded_step_wiring():
    """--dp-error-feedback end-to-end on a simulated 4-device DP mesh:
    the wrapped opt_state threads through the shard_map step, the
    residue becomes non-zero, and training stays finite."""
    r = run_in_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat, configs, optim
        from repro.distributed import compression as C
        from repro.models import lm
        from repro.runtime.context import MeshContext

        cfg = configs.get_smoke("llama-60m")
        ctx = MeshContext.create(mesh=compat.make_mesh((4,), ("data",)))
        spec = C.DPReduceSpec(level=2, detail_dtype=jnp.float8_e4m3fn,
                              error_feedback=True)
        opt = optim.make("adam", lr=1e-2)
        params = lm.init(cfg, jax.random.key(0))
        opt_state = {"opt": opt.init(params),
                     "dp_ef": C.ef_init(params, ctx.dp_size)}
        step = lm.make_train_step(cfg, opt, ctx=ctx, dp_reduce=spec)
        from repro.data.pipeline import SyntheticLM
        data = SyntheticLM(cfg.vocab, 32, 8, seed=0)
        with ctx.activate():
            step = jax.jit(step)
            for i in range(3):
                b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
                params, opt_state, m = step(params, opt_state, b)
        ef_mag = max(float(jnp.abs(l).max())
                     for l in jax.tree.leaves(opt_state["dp_ef"]))
        assert np.isfinite(float(m["loss"]))
        assert ef_mag > 0, ef_mag
        print("EF_OK loss=%.4f ef_max=%.2e" % (float(m["loss"]), ef_mag))
    """)
    assert "EF_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# Checkpoint manifests carry data provenance
# ---------------------------------------------------------------------------

def test_checkpoint_manifest_records_run_meta(tmp_path, corpus_dir):
    from repro.checkpoint.manager import CheckpointManager
    meta = {"data": {"kind": "corpus", "corpus_hash": "abc123",
                     "order_seed": 7}}
    cm = CheckpointManager(str(tmp_path), run_meta=meta)
    cm.save(4, {"x": np.arange(3)}, blocking=True)
    assert cm.manifest()["run"] == meta
    (tree, step) = cm.restore(None, {"x": np.zeros(3, np.int64)})
    assert step == 4
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.arange(3))
