"""Property tests for the adaptive-subspace rules (adarankgrad / rso).

hypothesis is optional: the conftest shim runs each property over a
fixed-seed sample grid (endpoints + midpoint per strategy) when it isn't
installed — same invariants, fewer draws.
"""

import jax
import jax.numpy as jnp
import numpy as np

from conftest import given, settings, st

from repro import optim
from repro.optim.lowrank import (_down, _effective_rank,
                                 _orth_rand_projector, _rotate_moments, _up)


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 32), st.integers(1, 4), st.integers(0, 1000))
def test_rso_projector_orthonormal(m, r, seed):
    """QR-orthonormalized random projector: PᵀP = I_r (m ≥ r always —
    ``_rank`` caps r at min(m, n))."""
    r = min(r, m)
    p = jnp.zeros((m, 2 * m))
    for left in (True, False):
        proj = _orth_rand_projector(jax.random.key(seed), p, r, left)
        assert proj.shape[-1] == r
        gram = np.asarray(jnp.swapaxes(proj, -1, -2) @ proj)
        np.testing.assert_allclose(gram, np.eye(r), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 8))
def test_rso_resample_seed_determinism(seed, epoch):
    """Same (seed, epoch) -> bitwise-identical projector (the resume
    contract: a restarted run redraws the exact same subspace); a
    different epoch draws a different one."""
    p = jnp.zeros((16, 32))
    key = jax.random.fold_in(jax.random.key(seed), epoch)
    p1 = _orth_rand_projector(key, p, 4, True)
    p2 = _orth_rand_projector(jax.random.fold_in(jax.random.key(seed),
                                                 epoch), p, 4, True)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    p3 = _orth_rand_projector(jax.random.fold_in(jax.random.key(seed),
                                                 epoch + 1), p, 4, True)
    assert not np.array_equal(np.asarray(p1), np.asarray(p3))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(0, 500))
def test_projection_idempotence(r, seed):
    """For an orthonormal projector, down∘up is the identity on the
    subspace: Pᵀ(P x) = x (left) and (x Pᵀ)P = x (right)."""
    for left in (True, False):
        p = jnp.zeros((16, 24))
        proj = _orth_rand_projector(jax.random.key(seed), p, r, left)
        low_shape = (r, 24) if left else (16, r)
        x = jax.random.normal(jax.random.key(seed + 1), low_shape)
        roundtrip = _down(_up(x, proj, left), proj, left)
        np.testing.assert_allclose(np.asarray(roundtrip), np.asarray(x),
                                   atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 16), st.integers(0, 200))
def test_effective_rank_bounds_and_tau_monotonicity(r_max, seed):
    """k ∈ [1, r_max]; k is non-decreasing in the energy fraction tau
    (more retained energy can only need more directions)."""
    s = jnp.sort(jnp.abs(jax.random.normal(jax.random.key(seed),
                                           (20,))))[::-1]
    ks = [float(_effective_rank(s, tau, r_max))
          for tau in (0.1, 0.5, 0.9, 0.99)]
    for k in ks:
        assert 1.0 <= k <= r_max
    assert ks == sorted(ks)


def test_effective_rank_exact_cases():
    # one dominant direction -> rank 1 regardless of tau < 1
    s = jnp.asarray([10.0, 0.0, 0.0, 0.0])
    assert float(_effective_rank(s, 0.9, 4)) == 1.0
    # flat spectrum: tau of the energy needs ceil(tau * k) directions
    s = jnp.ones((4,))
    assert float(_effective_rank(s, 0.9, 4)) == 4.0
    assert float(_effective_rank(s, 0.5, 4)) == 2.0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_moment_rotation_preserves_subspace_content(seed):
    """Rotating moments into the SAME basis is the identity (T = PᵀP = I);
    v stays nonnegative under any rotation (T∘T has nonneg entries)."""
    p = jnp.zeros((16, 32))
    proj = _orth_rand_projector(jax.random.key(seed), p, 4, True)
    m = jax.random.normal(jax.random.key(seed + 1), (4, 32))
    v = jnp.abs(jax.random.normal(jax.random.key(seed + 2), (4, 32)))
    h = {"m": m, "v": v}
    same = _rotate_moments(h, proj, proj, True)
    np.testing.assert_allclose(np.asarray(same["m"]), np.asarray(m),
                               atol=1e-5)
    other = _orth_rand_projector(jax.random.key(seed + 3), p, 4, True)
    rot = _rotate_moments(h, proj, other, True)
    assert float(jnp.min(rot["v"])) >= 0.0


def test_adarankgrad_rank_schedule_monotone():
    """Run the ACTUAL rule (update_gap=1: refresh every step) on gradients
    whose spectrum collapses over time; the per-leaf rank state must be
    monotone non-increasing — the schedule only tightens."""
    params = {"w": jax.random.normal(jax.random.key(0), (16, 32))}
    opt = optim.make("adarankgrad", lr=0.01, rank=8, update_gap=1, tau=0.5)
    st_ = opt.init(params)
    p = params
    traj = []
    for i in range(6):
        # progressively lower-rank gradients: top direction dominates more
        u = jax.random.normal(jax.random.key(10 + i), (16, 1))
        v = jax.random.normal(jax.random.key(20 + i), (1, 32))
        noise = jax.random.normal(jax.random.key(30 + i), (16, 32))
        g = {"w": u @ v + noise * (0.5 ** i)}
        p, st_ = jax.jit(opt.update)(g, st_, p)
        bname = [k for k in st_["buckets"] if k.startswith("adarankgrad")][0]
        traj.append(float(jnp.ravel(st_["buckets"][bname]["rank"])[0]))
    assert all(a >= b for a, b in zip(traj, traj[1:])), traj
    assert traj[-1] < 8.0  # it actually tightened on a collapsing spectrum


def test_adarankgrad_masked_projector_columns():
    """Columns past the live rank are exactly zero in the stored projector
    (masking is the static-shape realization of the dynamic rank)."""
    params = {"w": jax.random.normal(jax.random.key(0), (16, 32))}
    opt = optim.make("adarankgrad", lr=0.01, rank=8, update_gap=1, tau=0.5)
    st_ = opt.init(params)
    u = jax.random.normal(jax.random.key(1), (16, 1))
    v = jax.random.normal(jax.random.key(2), (1, 32))
    g = {"w": u @ v + 1e-3 * jax.random.normal(jax.random.key(3), (16, 32))}
    _, st_ = jax.jit(opt.update)(g, st_, params)
    bname = [k for k in st_["buckets"] if k.startswith("adarankgrad")][0]
    bstate = st_["buckets"][bname]
    k = int(jnp.ravel(bstate["rank"])[0])
    proj = np.asarray(bstate["proj"])[0]  # (m, r_max), bucket-stacked
    assert k < 8
    np.testing.assert_array_equal(proj[:, k:], 0.0)
    assert np.abs(proj[:, :k]).max() > 0.0
