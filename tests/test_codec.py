"""Optimizer-state substrate (repro.optim.codec): blocked-int8 property
tests, engine equivalence under the quantized codec, the
family × codec state_bytes sweep, and checkpoint transcoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.optim import codec, engine

from test_engine import layered_params, run_steps


# ---------------------------------------------------------------------------
# codec property tests
# ---------------------------------------------------------------------------

def _salt(seed, step=3, slot=0, leaf=5):
    return codec.slot_salt(codec.make_key(seed), jnp.uint32(step),
                           slot, jnp.uint32(leaf))


def test_uniform01_range_and_determinism():
    salt = _salt(0)
    idx = jnp.arange(4096, dtype=jnp.uint32)
    u = codec.uniform01(salt, idx)
    assert float(u.min()) >= 0.0 and float(u.max()) < 1.0
    # decent spread (counter-based hash, not a constant)
    assert 0.4 < float(u.mean()) < 0.6
    assert (u == codec.uniform01(salt, idx)).all()
    assert not (u == codec.uniform01(_salt(1), idx)).all()


def test_stochastic_rounding_unbiased():
    """E[dequant(quant(x))] == x: averaged over many independent salts the
    rounding bias vanishes (the deterministic-rounding alternative would
    sit a half-quantum off for every value with frac != 0.5)."""
    # fixed scale: pin one element per block to the absmax
    x = jnp.full((256,), 0.34e-2).at[::64].set(1.27)
    salts = jax.vmap(lambda i: _salt(0, step=i))(jnp.arange(512))
    q, s = jax.vmap(lambda k: codec.blocked_quant(x, k, 64))(salts)
    dec = jax.vmap(lambda qq, ss: codec.blocked_dequant(qq, ss, 64))(q, s)
    mean = dec.mean(axis=0)
    scale = 1.27 / 127.0
    err = (mean - x)[jnp.arange(256) % 64 != 0]
    # per-element: 512 draws -> se ~ 0.022*scale; allow ~5 sigma
    assert float(jnp.abs(err).max()) < 0.12 * scale
    # across elements the signed bias must cancel (~7 sigma bound)
    assert abs(float(err.mean())) < 0.01 * scale


@pytest.mark.parametrize("shape", [(130,), (63,), (1,), (13, 10), (4, 3, 9)])
def test_roundtrip_error_within_block_scale(shape):
    k = jax.random.key(hash(shape) % (2 ** 31))
    x = jax.random.normal(k, shape) * 3.0
    q, s = codec.blocked_quant(x, _salt(0), 64)
    assert q.shape == shape and q.dtype == jnp.int8
    assert s.shape == (codec.num_blocks(int(np.prod(shape)), 64),)
    dec = codec.blocked_dequant(q, s, 64)
    # stochastic rounding moves at most one quantum == one per-block scale
    flat_err = jnp.abs(dec - x).reshape(-1)
    pad = jnp.zeros(s.size * 64 - flat_err.size)
    per_block = jnp.concatenate([flat_err, pad]).reshape(s.size, 64)
    assert (per_block.max(axis=1) <= s + 1e-7).all()


def test_fixed_salt_requant_deterministic():
    x = jax.random.normal(jax.random.key(3), (77,))
    q1, s1 = codec.blocked_quant(x, _salt(7), 64)
    q2, s2 = codec.blocked_quant(x, _salt(7), 64)
    assert (q1 == q2).all() and (s1 == s2).all()
    q3, _ = codec.blocked_quant(x, _salt(8), 64)
    assert not (q1 == q3).all()


def test_zero_blocks_exact():
    x = jnp.zeros((130,))
    q, s = codec.blocked_quant(x, _salt(0), 64)
    assert (q == 0).all() and (s == 0).all()
    assert (codec.blocked_dequant(q, s, 64) == 0).all()


def test_absmax_representable():
    """The block absmax itself round-trips to within float error of ±127
    quanta — clipping can't push it out of range."""
    x = jnp.concatenate([jnp.full((64,), -5.0), jnp.full((64,), 5.0)])
    q, s = codec.blocked_quant(x, _salt(0), 64)
    assert (jnp.abs(q.astype(jnp.int32)) == 127).all()
    assert jnp.allclose(codec.blocked_dequant(q, s, 64), x, rtol=1e-6)


# ---------------------------------------------------------------------------
# engine equivalence under int8
# ---------------------------------------------------------------------------

Q8_CASES = [
    ("adam", {}), ("adam_mini", {}), ("muon", {}), ("sgd", {}),
    ("galore", {"rank": 4, "update_gap": 2}),
    ("apollo", {"rank": 4, "update_gap": 2}),
    ("fira", {"rank": 4, "update_gap": 2}),
    ("gwt", {"level": 2}),
]


@pytest.mark.parametrize("name,kw", Q8_CASES)
def test_bucketed_matches_unrolled_int8(name, kw):
    """The per-bucket scan wraps the leaf update in dequant→update→requant
    with per-(leaf, slot, step) salts — the same bits the unrolled
    per-leaf loop derives, so moments match BITWISE across layouts.
    Exception: GWT, where XLA fuses the Haar butterfly differently inside
    the scan body (≤1 f32 ulp, same as the f32 engine tier) — there an
    ulp near a rounding boundary may flip a quantum."""
    params = layered_params()
    p_b, st_b = run_steps(optim.make(name, lr=0.01, bucketed=True,
                                     state_codec="int8", **kw), params)
    p_u, st_u = run_steps(optim.make(name, lr=0.01, bucketed=False,
                                     state_codec="int8", **kw), params)
    if name == "gwt":
        def close(a, b):
            a, b = np.asarray(a), np.asarray(b)
            if a.dtype == np.int8:
                assert np.abs(a.astype(np.int32)
                              - b.astype(np.int32)).max() <= 1
            elif a.size:
                np.testing.assert_allclose(a.astype(np.float32),
                                           b.astype(np.float32), rtol=1e-5,
                                           atol=1e-6)
        jax.tree.map(close, st_b, st_u)
    else:
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), st_b, st_u)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-6), p_b, p_u)


def test_f32_codec_is_identity():
    """state_codec='f32' is pure passthrough: identical state STRUCTURE
    and bitwise-identical values vs the codec-less default."""
    params = layered_params()
    for name, kw in [("adam", {}), ("gwt", {"level": 2})]:
        p0, st0 = run_steps(optim.make(name, lr=0.01, **kw), params)
        p1, st1 = run_steps(optim.make(name, lr=0.01, state_codec="f32",
                                       **kw), params)
        assert jax.tree_util.tree_structure(st0) == \
            jax.tree_util.tree_structure(st1)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), st0, st1)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), p0, p1)


def test_gwt_fused_q8_matches_generic_wrap(kernel_impl):
    """impl='jnp' runs the engine's generic codec wrap around the scan
    body; fused impls requantize inside the kernel epilogue with the same
    salts.  Moments may differ by ≤1 quantum only where the two paths'
    f32 accumulation order lands an ulp apart across a rounding
    boundary."""
    if kernel_impl == "jnp":
        pytest.skip("needs a fused impl to compare against the wrap")
    params = layered_params(n_layers=2, d=16, f=32)
    p_j, st_j = run_steps(optim.make("gwt", lr=0.01, level=2, impl="jnp",
                                     state_codec="int8"), params)
    p_f, st_f = run_steps(optim.make("gwt", lr=0.01, level=2,
                                     impl=kernel_impl,
                                     state_codec="int8"), params)

    def close(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:
            assert np.abs(a.astype(np.int32) - b.astype(np.int32)).max() <= 1
        else:
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32), rtol=1e-5,
                                       atol=1e-5)
    jax.tree.map(close, st_j, st_f)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-4), p_j, p_f)


def test_gwt_fused_q8_3d_leaf_matches_generic_wrap():
    """3-D+ leaves (e.g. qwen GQA tensors): the codec blocks/salts over
    the leaf's row-major flat order, so the fused path must merge the
    extra dims into the row axis rather than vmapping over them —
    regression for a vmap-axis mismatch on (L, extra, m, n) buckets.
    Pinned to ``interpret`` (not the ``kernel_impl`` sweep) so the guard
    runs in the default tier."""
    kernel_impl = "interpret"
    key = jax.random.key(7)
    params = {"w3d": jax.random.normal(key, (2, 24, 16)) * 0.1,
              "w2d": jax.random.normal(jax.random.key(8), (16, 16)) * 0.1}
    p_j, st_j = run_steps(optim.make("gwt", lr=0.01, level=2, impl="jnp",
                                     state_codec="int8"), params)
    p_f, st_f = run_steps(optim.make("gwt", lr=0.01, level=2,
                                     impl=kernel_impl,
                                     state_codec="int8"), params)

    def close(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:
            assert np.abs(a.astype(np.int32) - b.astype(np.int32)).max() <= 1
        else:
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32), rtol=1e-5,
                                       atol=1e-5)
    jax.tree.map(close, st_j, st_f)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-4), p_j, p_f)


@pytest.mark.parametrize("level,shape", [
    (1, (16, 64)), (2, (16, 64)), (4, (16, 64)),   # LAST orientation
    (2, (32, 7)),                                  # FIRST orientation
])
def test_gwt_fused_q8_level_orientation_sweep(level, shape):
    """Megakernel parity tier × int8 codec: the fused dequant→update→
    requant epilogue matches the generic codec wrap across transform
    levels and both orientations, with the same ≤1-quantum comparator as
    the q8 wrap tier.  Pinned to ``interpret`` so it runs by default."""
    k = jax.random.key(17)
    params = {"blk": {"mlp": {
        "w1": jax.random.normal(k, shape) * 0.1,
        "w2": jax.random.normal(jax.random.fold_in(k, 1), shape) * 0.1}}}
    p_j, st_j = run_steps(optim.make("gwt", lr=0.01, level=level,
                                     impl="jnp", state_codec="int8"),
                          params)
    p_f, st_f = run_steps(optim.make("gwt", lr=0.01, level=level,
                                     impl="interpret", state_codec="int8"),
                          params)

    def close(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:
            assert np.abs(a.astype(np.int32) - b.astype(np.int32)).max() <= 1
        else:
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32), rtol=1e-5,
                                       atol=1e-5)
    jax.tree.map(close, st_j, st_f)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-4), p_j, p_f)


def test_gwt_fused_q8_nontileable_shape_uses_oracle():
    """A bucket whose flattened A-band (m·n_A = 48) is not a codec-block
    multiple cannot tile block-aligned — the ops layer must route it to
    the jnp oracle under fused impls instead of launching a kernel that
    would straddle scale blocks across row tiles.  The engine result must
    stay finite and match the generic wrap."""
    from repro.kernels.gwt_adam import kernel as kg
    assert kg.q8_row_block(12, 8, 1, 64) is None
    params = {"blk": {"w": jax.random.normal(jax.random.key(23),
                                             (12, 8)) * 0.1}}
    p_j, st_j = run_steps(optim.make("gwt", lr=0.01, level=1,
                                     impl="jnp", state_codec="int8"),
                          params)
    p_f, st_f = run_steps(optim.make("gwt", lr=0.01, level=1,
                                     impl="interpret", state_codec="int8"),
                          params)
    assert np.isfinite(np.asarray(p_f["blk"]["w"], np.float32)).all()

    def close(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:
            assert np.abs(a.astype(np.int32) - b.astype(np.int32)).max() <= 1
        else:
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32), rtol=1e-5,
                                       atol=1e-5)
    jax.tree.map(close, st_j, st_f)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-4), p_j, p_f)


def test_codec_key_advances_rounding_per_step():
    """Salts fold in the step: the same moment value requantized at two
    different steps draws different rounding bits (no frozen bias)."""
    x = jax.random.normal(jax.random.key(0), (256,))
    key = codec.make_key(0)
    q1, _ = codec.blocked_quant(
        x, codec.slot_salt(key, jnp.uint32(1), 0, jnp.uint32(0)), 64)
    q2, _ = codec.blocked_quant(
        x, codec.slot_salt(key, jnp.uint32(2), 0, jnp.uint32(0)), 64)
    assert not (q1 == q2).all()


# ---------------------------------------------------------------------------
# state_bytes sweep: 8 families x both codecs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw", Q8_CASES)
def test_state_bytes_sweep(name, kw):
    """eval_shape accounting == realized bytes for both codecs, and int8
    strictly shrinks every moment-bearing family."""
    params = layered_params()
    sizes = {}
    for cdc in ("f32", "int8"):
        opt = optim.make(name, lr=0.01, state_codec=cdc, **kw)
        st = opt.init(params)
        claimed = engine.state_bytes(opt, params)
        realized = sum(l.size * l.dtype.itemsize
                       for l in jax.tree.leaves(st))
        assert claimed == realized
        sizes[cdc] = claimed
    assert sizes["int8"] < sizes["f32"]
    # int8 moments + f32 scales: at worst 1/4 + 1/(4*64) of the f32 bytes
    # for the moment slots, so even projector-heavy families shrink >25%
    assert sizes["int8"] < 0.75 * sizes["f32"]


# ---------------------------------------------------------------------------
# transcoding (checkpoint codec migration)
# ---------------------------------------------------------------------------

def test_transcode_f32_int8_roundtrip():
    params = layered_params()
    opt32 = optim.make("gwt", lr=0.01, level=2)
    opt8 = optim.make("gwt", lr=0.01, level=2, state_codec="int8")
    _, st32 = run_steps(opt32, params)

    st8 = engine.transcode(st32, params, opt32, opt8)
    like8 = jax.eval_shape(opt8.init, params)
    assert jax.tree_util.tree_structure(st8) == \
        jax.tree_util.tree_structure(like8)
    assert int(st8["step"]) == int(st32["step"])

    back = engine.transcode(st8, params, opt8, opt32)
    assert jax.tree_util.tree_structure(back) == \
        jax.tree_util.tree_structure(st32)

    # one quantization round trip: error bounded by the per-block scale
    def close(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.size:
            tol = max(1e-7, np.abs(a).max() / 127.0 * 1.01)
            assert np.abs(a - b).max() <= tol
    jax.tree.map(close, st32["buckets"], back["buckets"])

    # stable under re-encoding: same dst codec key + step, input already on
    # the quantization grid -> identical codes; the block scale itself may
    # move one f32 ulp (absmax reconstructed as 127*s/127)
    st8b = engine.transcode(back, params, opt32, opt8)

    def stable(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:
            np.testing.assert_array_equal(a, b)
        elif a.size:
            np.testing.assert_allclose(a, b, rtol=1e-6)
    jax.tree.map(stable, st8, st8b)


def test_int8_states_still_step_after_transcode():
    params = layered_params(n_layers=2)
    opt32 = optim.make("adam", lr=0.01)
    opt8 = optim.make("adam", lr=0.01, state_codec="int8")
    _, st32 = run_steps(opt32, params)
    st8 = engine.transcode(st32, params, opt32, opt8)
    g = jax.tree.map(lambda x: x * 0.01, params)
    p2, st2 = opt8.update(g, st8, params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(p2))
    assert int(st2["step"]) == int(st8["step"]) + 1


# ---------------------------------------------------------------------------
# sharding mirrors the encoded layout
# ---------------------------------------------------------------------------

def test_gwt_state_shardings_match_encoded_structure():
    """gwt_state_shardings(state_codec='int8') must produce exactly one
    NamedSharding per leaf of the encoded opt_state (q + scale slots,
    codec_key included) — device_put of the real init succeeds leafwise."""
    from repro import compat, configs
    from repro.distributed import sharding as shr
    from repro.models import lm

    cfg = configs.get_smoke("llama-60m")
    mesh = compat.make_mesh((1,), ("data",))
    params_abs = lm.abstract_params(cfg)
    for cdc in ("f32", "int8"):
        sh = shr.gwt_state_shardings(params_abs, lm.param_axes(cfg), mesh,
                                     shr.train_rules(mesh), level=2,
                                     state_codec=cdc)
        opt = optim.make("gwt", lr=0.01, level=2, state_codec=cdc)
        st_abs = jax.eval_shape(opt.init, params_abs)
        assert jax.tree_util.tree_structure(
            jax.tree.map(lambda _: 0, sh)) == \
            jax.tree_util.tree_structure(jax.tree.map(lambda _: 0, st_abs))
