"""Data pipeline, checkpointing (incl. resharding restore), fault-tolerant
runtime, wavelet-compressed DP reduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_devices

from repro.checkpoint.manager import CheckpointManager, StructureMismatch
from repro.data.pipeline import ByteLM, Prefetcher, SyntheticLM
from repro.runtime.fault_tolerance import StepWatchdog, TrainLoop


def test_synthetic_deterministic_and_resumable():
    src = SyntheticLM(vocab=512, seq_len=32, batch_size=4, seed=7)
    b1 = src.batch(10)
    b2 = SyntheticLM(vocab=512, seq_len=32, batch_size=4, seed=7).batch(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_bytelm_reads_repo():
    src = ByteLM("src/**/*.py", seq_len=64, batch_size=2, seed=0)
    b = src.batch(0)
    assert b["tokens"].shape == (2, 64)
    assert b["tokens"].max() < 256


def test_prefetcher_resumes_at_step():
    src = SyntheticLM(vocab=128, seq_len=8, batch_size=2, seed=1)
    pf = Prefetcher(src, start_step=5)
    i, b = next(pf)
    pf.close()
    assert i == 5
    np.testing.assert_array_equal(b["tokens"], src.batch(5)["tokens"])


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "step": jnp.int32(7),
            "nested": {"v": jnp.ones((2, 2), jnp.float32) * 0.5}}
    cm = CheckpointManager(str(tmp_path))
    cm.save(3, tree, blocking=True)
    restored, step = cm.restore(None, tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), gc_keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        cm.save(s, tree, blocking=True)
    assert cm.committed_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_async_then_wait(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.ones((64, 64))}
    cm.save(1, tree)            # async
    cm.wait()
    assert cm.latest_step() == 1


def test_restore_reshards_under_new_mesh(tmp_path):
    """Elastic scaling: save single-device, restore under an 8-device mesh
    in a subprocess (own XLA_FLAGS)."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, tree, blocking=True)
    code = f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.checkpoint.manager import CheckpointManager
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cm = CheckpointManager({str(tmp_path)!r})
        like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        restored, step = cm.restore(None, like, shardings=sh)
        assert step == 1
        arr = restored["w"]
        assert len(arr.sharding.device_set) == 8
        np.testing.assert_array_equal(
            np.asarray(arr), np.arange(64, dtype=np.float32).reshape(8, 8))
        print("RESHARD_OK")
    """
    r = run_in_devices(8, code, timeout=300)
    assert "RESHARD_OK" in r.stdout, r.stdout + r.stderr


def test_watchdog_flags_stragglers():
    logs = []
    wd = StepWatchdog(slow_factor=2.0, log=logs.append)
    import time
    for i, d in enumerate([0.01, 0.01, 0.01, 0.08, 0.01]):
        wd.start()
        time.sleep(d)
        wd.stop(i)
    assert wd.incidents >= 1
    assert any("watchdog" in l for l in logs)


def test_train_loop_checkpoints_and_resumes(tmp_path):
    """End-to-end fault tolerance: run 6 steps w/ ckpt_every=5, 'crash',
    resume from step 5, data stream stays aligned."""
    from repro import configs, optim
    from repro.models import lm
    cfg = configs.LLAMA["llama-60m"].with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab=256)
    key = jax.random.key(0)
    params = lm.init(cfg, key)
    opt = optim.make("gwt", lr=1e-3, level=2)
    ostate = opt.init(params)
    data = SyntheticLM(cfg.vocab, 16, 4, seed=0)
    cm = CheckpointManager(str(tmp_path))
    step_fn = jax.jit(lm.make_train_step(cfg, opt))
    loop = TrainLoop(step_fn, cm, data, ckpt_every=5, log_every=100,
                     log=lambda s: None)
    p1, o1, losses1 = loop.run(params, ostate, num_steps=6)
    assert cm.latest_step() == 5

    (saved, start) = cm.restore(None, {"params": params, "opt": ostate})
    loop2 = TrainLoop(step_fn, cm, data, ckpt_every=5, log_every=100,
                      log=lambda s: None)
    p2, o2, losses2 = loop2.run(saved["params"], saved["opt"],
                                start_step=start, num_steps=6)
    # the resumed step 5->6 must consume the same batch: loss matches
    np.testing.assert_allclose(losses2[0], losses1[5], rtol=1e-4)


def test_wavelet_compressed_psum_close_to_exact():
    """Compressed DP reduction ≈ exact mean; approximation band exact."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.distributed.compression import make_compressed_grad_reducer
        mesh = compat.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.key(0), (8, 16, 64))
        reducer = make_compressed_grad_reducer(mesh, level=2)
        with compat.use_mesh(mesh):
            out = jax.jit(reducer)({"w": g})["w"]
        exact = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
        err = float(jnp.abs(out - exact).max())
        rel = err / float(jnp.abs(exact).max())
        assert rel < 0.02, rel       # bf16 detail quantization only
        print("COMPRESS_OK", rel)
    """
    r = run_in_devices(8, code, timeout=300)
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr


def test_compression_wire_bytes_accounting():
    from repro.distributed.compression import wire_bytes
    n = 1024
    full = 2 * n * 4
    l2 = wire_bytes(n, 2)
    assert l2 < full
    assert l2 == 2 * (256 * 4 + 768 * 2)


def test_checkpoint_uncommitted_is_invisible(tmp_path):
    """A crash mid-write (no COMMITTED marker) must not be restorable."""
    import shutil
    cm = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.ones((4,))}
    cm.save(1, tree, blocking=True)
    # simulate a torn write at step 2
    d = cm._step_dir(2)
    shutil.copytree(cm._step_dir(1), d)
    import os as _os
    _os.remove(_os.path.join(d, "COMMITTED"))
    assert cm.latest_step() == 1


def test_checkpoint_structure_mismatch_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"x": jnp.ones((4,))}, blocking=True)
    with pytest.raises(StructureMismatch):
        cm.restore(None, {"x": jnp.ones((4,)), "extra": jnp.ones((2,))})
    # shape drift is also caught (typed, so callers can run a migration)
    with pytest.raises(StructureMismatch):
        cm.restore(None, {"x": jnp.ones((2, 2))})


def test_watchdog_splits_dispatch_and_block():
    """Satellite fix: the watchdog reports dispatch (async enqueue) and
    blocked (host stalled on device) phases separately — a device-side
    straggler shows up as a block incident even when dispatch stays fast."""
    logs = []
    wd = StepWatchdog(slow_factor=2.0, log=logs.append)
    for i in range(4):
        wd.start()
        wd.stop(i, n_steps=2)
        wd.block(0.002, n_steps=2)
    before = wd.incidents
    wd.block(0.5, n_steps=1, step=99)
    assert wd.incidents == before + 1
    assert any("blocked" in line for line in logs)
    s = wd.summary()
    assert s["dispatch_s_per_step"] is not None
    assert s["blocked_s_per_step"] is not None
    assert s["incidents"] == wd.incidents
