"""Pipelined donated train-step runtime: accumulation equivalence,
donation safety (single-buffered state, use-after-donation), pipelined
loop ≡ eager loop, snapshot-then-save under donation, and SIGTERM
preempt → --resume bitwise determinism through the launcher."""

import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.data.pipeline import (SyntheticLM, WithEncoderFrames,
                                 stack_batches)
from repro.models import lm
from repro.optim.engine import jit_update, live_update_bytes, state_bytes
from repro.runtime.fault_tolerance import TrainLoop

SMOKE = configs.get_smoke("llama-60m")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _init(seed=0):
    params = lm.init(SMOKE, jax.random.key(seed))
    return params


# ---------------------------------------------------------------------------
# Satellite: accumulation equivalence
# ---------------------------------------------------------------------------

ACCUM_CASES = [
    # (name, kwargs, rtol, atol): sgd/adam match to float-accumulation
    # reduction order (the k-microbatch f32 sum reassociates the global
    # reduction, so exact bitwise equality is impossible by construction —
    # observed ≤1 ulp for sgd); gwt's variance-normalized update amplifies
    # that ulp at step 1 (v ≈ 0), hence the looser band.
    ("sgd", {}, 1e-5, 1e-6),
    ("adam", {}, 2e-4, 2e-5),
    ("galore", {"rank_frac": 0.25, "update_gap": 100}, 2e-4, 2e-5),
    ("gwt", {"level": 2}, 5e-2, 2e-2),
]


@pytest.mark.parametrize("name,kw,rtol,atol", ACCUM_CASES)
def test_accum_matches_concatenated_batch(name, kw, rtol, atol):
    """accum_steps=k over k microbatches == one accum_steps=1 step on the
    concatenated global batch (same shard-preserving layout)."""
    data = SyntheticLM(SMOKE.vocab, 32, 8, seed=3)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    opt = optim.make(name, lr=1e-2, **kw)
    params = _init()
    st = opt.init(params)
    one = jax.jit(lm.make_train_step(SMOKE, opt, accum_steps=1))
    split = jax.jit(lm.make_train_step(SMOKE, opt, accum_steps=4))
    p1, s1, m1 = one(params, st, batch)
    p4, s4, m4 = split(params, st, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Tentpole: donation — single-buffered state, strict use-after-donation
# ---------------------------------------------------------------------------

def test_donated_update_single_buffers_state():
    """XLA buffer assignment: with (grads, state) donated, peak live bytes
    drop by ~the optimizer-state size (no old+new double buffering)."""
    opt = optim.make("adam", lr=1e-3)
    params = _init()
    st = opt.init(params)
    grads = jax.tree.map(lambda p: p * 0.01, params)
    plain = jit_update(opt, donate=False).lower(grads, st, params).compile()
    donated = jit_update(opt, donate=True).lower(grads, st, params).compile()
    lp, ld = live_update_bytes(plain), live_update_bytes(donated)
    if lp is None or ld is None:
        pytest.skip("backend exposes no memory_analysis")
    sb = state_bytes(opt, params)
    assert ld < lp, (ld, lp)
    # at least the full optimizer state must have aliased through
    assert lp - ld >= sb, (lp, ld, sb)


def test_donated_train_step_invalidates_inputs():
    """donate=True threads donate_argnums through make_train_step: the
    passed-in params/opt_state buffers are consumed — a reuse must raise
    (never silently read stale memory)."""
    opt = optim.make("gwt", lr=1e-3, level=2)
    params = _init()
    st = opt.init(params)
    data = SyntheticLM(SMOKE.vocab, 32, 4, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    step = lm.make_train_step(SMOKE, opt, donate=True)
    p2, s2, _ = step(params, st, batch)
    jax.block_until_ready(p2)
    donated_leaf = jax.tree.leaves(params)[0]
    assert donated_leaf.is_deleted()
    with pytest.raises(RuntimeError):
        np.asarray(donated_leaf)
    # the new buffers are live and usable for the next step
    p3, s3, _ = step(p2, s2, batch)
    assert np.isfinite(np.asarray(jax.tree.leaves(p3)[0])).all()


# ---------------------------------------------------------------------------
# Tentpole: pipelined superstep loop ≡ eager per-step loop
# ---------------------------------------------------------------------------

def test_pipelined_loop_matches_eager_loop():
    """Same trajectory through both loop modes.  The eager loop compiles
    one step per dispatch while the superstep compiles a scanned body —
    XLA fuses them differently, so agreement is semantic (gwt's
    variance-normalized update amplifies the per-step ulp drift to ~1e-3
    relative over 12 steps), not bitwise.  Bitwise determinism between
    *pipelined* runs is covered below and at launcher level."""
    data = SyntheticLM(SMOKE.vocab, 32, 4, seed=1)
    opt = optim.make("gwt", lr=1e-2, level=2)
    params = _init()
    st = opt.init(params)

    eager = TrainLoop(jax.jit(lm.make_train_step(SMOKE, opt)), None, data,
                      log_every=5, log=lambda s: None, pipelined=False)
    pe, se, le = eager.run(*jax.tree.map(lambda a: a.copy(), (params, st)),
                           num_steps=12)

    pipe = TrainLoop(lm.make_train_step(SMOKE, opt), None, data,
                     log_every=5, max_chunk=4, log=lambda s: None)
    pp, sp, lp = pipe.run(params, st, num_steps=12)

    assert len(le) == len(lp) == 12
    np.testing.assert_allclose(le, lp, rtol=2e-2)
    for a, b in zip(jax.tree.leaves(pe), jax.tree.leaves(pp)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_pipelined_resume_partition_is_bitwise_deterministic():
    """Stopping at a chunk boundary and resuming in a FRESH loop replays
    bit-identical steps: chunk boundaries live on an absolute step grid,
    so the resumed run's partition is exactly the suffix of the
    uninterrupted run's.  (Per-step numerics DO depend on scan trip
    count — XLA fuses different chunk lengths differently — which is why
    the grid must be absolute, not relative to the restart point.)"""
    opt = optim.make("gwt", lr=1e-2, level=2)

    def make_loop():
        data = SyntheticLM(SMOKE.vocab, 32, 4, seed=1)
        return TrainLoop(lm.make_train_step(SMOKE, opt), None, data,
                         log_every=5, max_chunk=4, log=lambda s: None)

    params = _init()
    st = opt.init(params)
    pa, sa, la = make_loop().run(
        *jax.tree.map(lambda a: a.copy(), (params, st)), num_steps=12)

    # interrupted at step 8 (a grid point), resumed by a fresh loop
    pm, sm, l1 = make_loop().run(params, st, num_steps=8)
    pb, sb, l2 = make_loop().run(pm, sm, start_step=8, num_steps=12)

    np.testing.assert_array_equal(np.asarray(la), np.asarray(l1 + l2))
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_snapshot_save_does_not_race_donation(tmp_path):
    """Checkpoints during a donating pipelined run come from on-device
    snapshots: the async writer must serialize valid data even though the
    loop immediately donates the live buffers to the next chunk."""
    from repro.checkpoint.manager import CheckpointManager
    data = SyntheticLM(SMOKE.vocab, 32, 4, seed=2)
    opt = optim.make("adam", lr=1e-2)
    params = _init()
    st = opt.init(params)
    cm = CheckpointManager(str(tmp_path))
    loop = TrainLoop(lm.make_train_step(SMOKE, opt), cm, data,
                     ckpt_every=4, log_every=100, max_chunk=4,
                     log=lambda s: None, save_final=True)
    p, s, losses = loop.run(params, st, num_steps=10)
    cm.wait()
    assert cm.latest_step() == 10          # save_final
    assert 4 in cm.committed_steps() or 8 in cm.committed_steps()
    saved, step = cm.restore(None, {"params": p, "opt": s})
    assert step == 10
    # the final checkpoint holds exactly the returned (live) params
    for a, b in zip(jax.tree.leaves(saved["params"]), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Satellite: data-pipeline adapter (ex-monkey-patch) + chunk stacking
# ---------------------------------------------------------------------------

def test_encoder_frames_adapter_deterministic():
    base = SyntheticLM(128, 16, 4, seed=5)
    src = WithEncoderFrames(base, n_frames=4, d_model=8)
    b = src.batch(7)
    assert b["enc_embeds"].shape == (4, 4, 8)
    assert b["enc_embeds"].dtype == np.float32
    again = WithEncoderFrames(SyntheticLM(128, 16, 4, seed=5), 4, 8).batch(7)
    np.testing.assert_array_equal(b["enc_embeds"], again["enc_embeds"])
    np.testing.assert_array_equal(b["tokens"], again["tokens"])


def test_stack_batches_layout():
    src = SyntheticLM(64, 8, 2, seed=0)
    bs = [src.batch(i) for i in range(3)]
    chunk = stack_batches(bs)
    assert chunk["tokens"].shape == (3, 2, 8)
    np.testing.assert_array_equal(chunk["labels"][1], bs[1]["labels"])


# ---------------------------------------------------------------------------
# Satellite: SIGTERM preempt → --resume bitwise determinism (launcher-level)
# ---------------------------------------------------------------------------

def _launch(ckpt_dir, extra=(), wait=True, timeout=600, steps=120):
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "llama-60m", "--smoke", "--optimizer", "gwt",
           "--level", "2", "--lr", "0.01", "--steps", str(steps),
           "--batch", "2", "--seq", "32", "--log-every", "4",
           "--ckpt-every", "8", "--ckpt-dir", str(ckpt_dir), *extra]
    env = dict(os.environ, PYTHONPATH="src", JAX_ENABLE_CHECKS="1",
               JAX_PLATFORMS="cpu")
    proc = subprocess.Popen(cmd, cwd=REPO, env=env,
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True)
    if not wait:
        return proc
    out, err = proc.communicate(timeout=timeout)
    assert proc.returncode == 0, out + err
    return out + err


def _final_leaves(ckpt_dir, step=120):
    d = os.path.join(str(ckpt_dir), f"step_{step:09d}")
    assert os.path.exists(os.path.join(d, "COMMITTED")), os.listdir(ckpt_dir)
    blobs = {}
    for name in sorted(os.listdir(d)):
        if name.endswith(".bin"):
            with open(os.path.join(d, name), "rb") as f:
                blobs[name] = f.read()
    return blobs


def _interrupt_then_resume(a, extra=(), resume_extra=None, steps=120):
    """Start a run, SIGTERM it once the first checkpoint commits, resume
    it to completion.  ``resume_extra`` defaults to ``extra`` (pass a
    different tuple to change flags across the restart)."""
    proc = _launch(a, extra=extra, wait=False, steps=steps)
    deadline = time.time() + 570
    first_ckpt = os.path.join(str(a), "step_000000008", "COMMITTED")
    while time.time() < deadline and proc.poll() is None \
            and not os.path.exists(first_ckpt):
        time.sleep(0.05)
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
        out, err = proc.communicate(timeout=120)
        assert proc.returncode == 0, out + err
    else:
        out, err = proc.communicate()
        assert proc.returncode == 0, out + err
    resumed_needed = not os.path.exists(
        os.path.join(str(a), f"step_{steps:09d}", "COMMITTED"))
    log = _launch(a, extra=(*(extra if resume_extra is None
                              else resume_extra), "--resume"), steps=steps)
    if resumed_needed:
        assert "resumed from step" in log, log
    return log


@pytest.mark.parametrize("seed", [0])
def test_sigterm_preempt_then_resume_is_bitwise(tmp_path, seed):
    """Kill a run mid-training (SIGTERM → synchronous checkpoint → exit 0),
    restart with --resume, and require the final checkpoint — params AND
    optimizer state — to be byte-identical to an uninterrupted run: the
    data stream realigns and the absolute chunk grid reproduces the exact
    scan groupings (JAX strict checks on; donation misuse would raise)."""
    a, b = tmp_path / "interrupted", tmp_path / "straight"
    _interrupt_then_resume(a)
    _launch(b)

    la, lb = _final_leaves(a), _final_leaves(b)
    assert la.keys() == lb.keys()
    for name in la:
        assert la[name] == lb[name], f"leaf {name} differs after resume"


def test_sigterm_resume_int8_codec_is_bitwise(tmp_path):
    """SIGTERM + --resume under --state-codec int8 must reproduce the
    uninterrupted int8 run byte-for-byte — q codes, block scales, and
    params included.  The stochastic-rounding stream is a pure function
    of (codec_key, step, slot, leaf): the key lives in the checkpointed
    opt_state, so the resumed run redraws the exact same rounding bits."""
    extra = ("--state-codec", "int8")
    a, b = tmp_path / "interrupted", tmp_path / "straight"
    _interrupt_then_resume(a, extra=extra, steps=48)
    _launch(b, extra=extra, steps=48)

    la, lb = _final_leaves(a, step=48), _final_leaves(b, step=48)
    assert la.keys() == lb.keys()
    for name in la:
        assert la[name] == lb[name], f"leaf {name} differs after resume"


def test_resume_transcodes_codec_change(tmp_path):
    """A --resume whose --state-codec differs from the checkpoint's
    transcodes the optimizer state in place (f32 checkpoint → int8 run)
    instead of failing the structure check, and trains on."""
    a = tmp_path / "ck"
    _launch(a, steps=16)
    log = _launch(a, extra=("--state-codec", "int8", "--resume"), steps=32)
    assert "transcoded optimizer state f32 -> int8" in log, log
    assert "resumed from step 16" in log, log
    _final_leaves(a, step=32)  # committed and loadable


def test_sigterm_resume_corpus_worker_count_bitwise(tmp_path):
    """The corpus source through the launcher: SIGTERM mid-run with
    PROCESS workers, then --resume with the plain prefetch thread (a
    worker-count change across the restart), must reproduce the
    uninterrupted thread-loaded run byte-for-byte — sample order is a
    pure function of the step, so loader state never enters the
    checkpoint and worker topology never enters the numerics.  Streaming
    eval rides along to pin that eval boundaries join the absolute chunk
    grid deterministically."""
    from repro.data import build_corpus
    corpus = tmp_path / "corpus"
    build_corpus.build(os.path.join(REPO, "tests", "fixtures", "corpus",
                                    "*.txt"),
                       str(corpus), tokenizer_kind="bpe", vocab_size=512)
    base = ("--data", "corpus", "--corpus-dir", str(corpus),
            "--eval-every", "16", "--eval-batches", "2")
    a, b = tmp_path / "interrupted", tmp_path / "straight"
    _interrupt_then_resume(a, extra=(*base, "--workers", "2"),
                           resume_extra=(*base, "--workers", "0"),
                           steps=48)
    _launch(b, extra=(*base, "--workers", "0"), steps=48)

    la, lb = _final_leaves(a, step=48), _final_leaves(b, step=48)
    assert la.keys() == lb.keys()
    for name in la:
        assert la[name] == lb[name], f"leaf {name} differs after resume"
