"""Observability subsystem (DESIGN.md §12): metric sinks, span tracing,
on-device optimizer taps, and their TrainLoop / serve-engine plumbing.

The tap oracle tests compare values computed INSIDE the jitted
``tapped_update`` graph against independently jitted jnp reference
graphs and assert bitwise equality — CPU XLA is deterministic and both
graphs perform the same reductions in the same order.  Random
(non-degenerate) inputs matter here: constant inputs expose FMA
contraction differences between fused and unfused graphs in the last
ulp, which is exactly the noise the random draw keeps out of the
contract.
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs, optim
from repro.core import haar, limiter
from repro.obs import trace as obs_trace
from repro.obs.sink import JsonlSink, MemorySink, NullSink, Telemetry
from repro.optim.engine import _codec_taps
from repro.runtime.fault_tolerance import StepWatchdog, TrainLoop


@pytest.fixture(autouse=True)
def _reset_global_telemetry():
    """Tests install process-global sinks; always restore the null one."""
    yield
    obs.shutdown()


# ---------------------------------------------------------------------------
# Watchdog incident ring buffer
# ---------------------------------------------------------------------------

def _escalate(wd, n):
    """Feed geometrically growing blocked-phase samples: each is far above
    slow_factor x the EMA it left behind, so every sample past the first
    is an incident."""
    wd.block(1e-3)                 # seeds the EMA, no incident
    for k in range(n):
        wd.block(10.0 ** (k + 1))


def test_watchdog_ring_buffer_caps_records_keeps_exact_count():
    wd = StepWatchdog(slow_factor=2.0, log=lambda s: None, max_incidents=4)
    _escalate(wd, 10)
    assert wd.incidents == 10            # exact total (int back-compat)
    assert isinstance(wd.incidents, int)
    assert len(wd.incident_log) == 4     # ring keeps only the newest
    assert wd.incidents_dropped == 6
    assert [r["id"] for r in wd.incident_log] == [7, 8, 9, 10]
    assert all(r["phase"] == "blocked" for r in wd.incident_log)


def test_watchdog_summary_folds_ring_and_reaches_sink():
    sink = MemorySink()
    obs.configure(sink=sink)
    wd = StepWatchdog(slow_factor=2.0, log=lambda s: None, max_incidents=3)
    _escalate(wd, 5)
    s = wd.summary()
    assert s["incidents"] == 5
    assert s["incidents_dropped"] == 2
    assert s["incident_log"] == list(wd.incident_log)
    assert isinstance(s["incident_log"], list)  # JSON-serializable fold
    json.dumps(s["incident_log"])
    # every incident was also emitted live to the process-global sink
    live = [r for r in sink.records if r["kind"] == "watchdog_incident"]
    assert [r["id"] for r in live] == [1, 2, 3, 4, 5]


def test_watchdog_below_threshold_never_logs():
    wd = StepWatchdog(slow_factor=3.0, log=lambda s: None)
    for _ in range(20):
        wd.block(1e-3)
    assert wd.incidents == 0 and wd.incidents_dropped == 0


# ---------------------------------------------------------------------------
# On-device taps vs jnp oracles
# ---------------------------------------------------------------------------

def _tap_setup(seed=0, shape=(8, 16), codec="f32", impl=None, gamma=1.01):
    kw = {"state_codec": codec}
    if impl is not None:
        kw["impl"] = impl
    opt = optim.make("gwt", lr=1e-2, level=2, gamma=gamma, **kw)
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    params = {"w1": jax.random.normal(k1, shape, jnp.float32),
              "w2": jax.random.normal(k2, shape, jnp.float32)}
    grads = jax.tree.map(
        lambda _, k: jax.random.normal(k, shape, jnp.float32),
        params, {"w1": k3, "w2": jax.random.fold_in(k3, 1)})
    return opt, params, grads


def test_tapped_update_outputs_bitwise_identical_to_plain():
    """The metrics-off guarantee at the engine layer: taps are pure side
    outputs — params and state from ``tapped_update`` match ``update``
    bitwise."""
    opt, params, grads = _tap_setup()
    st = opt.init(params)
    p_a, st_a = jax.jit(opt.update)(grads, st, params)
    p_b, st_b, taps = jax.jit(opt.tapped_update)(grads, st, params)
    assert taps  # the side channel is actually populated
    jax.tree.map(np.testing.assert_array_equal, p_a, p_b)
    jax.tree.map(np.testing.assert_array_equal, st_a, st_b)


def test_tap_values_match_jnp_oracle(kernel_impl):
    """grad/update/band-energy taps == an independently jitted jnp
    reference, bitwise, on the fused-kernel backend under test."""
    opt, params, grads = _tap_setup(impl=kernel_impl)
    st = opt.init(params)
    new_p, new_st, taps = jax.jit(opt.tapped_update)(grads, st, params)
    (bname,) = {k.split("/")[0] for k in taps}
    swap = "first" in bname

    @jax.jit
    def oracle(g_stk, p_stk, np_stk, new_pn):
        g32 = g_stk.astype(jnp.float32)
        d32 = np_stk.astype(jnp.float32) - p_stk.astype(jnp.float32)
        gt32 = (jnp.swapaxes(g_stk, -1, -2) if swap
                else g_stk).astype(jnp.float32)
        # full-DWT reference: the tap's approx-chain-plus-Parseval
        # derivation must agree with it bitwise on the approx band
        a, _ = haar.haar_forward(gt32, 2)
        band_a = jnp.sum(a * a)
        return {"grad_ssq": jnp.sum(g32 * g32),
                "update_ssq": jnp.sum(d32 * d32),
                "band_a_ssq": band_a,
                "band_d_ssq": jnp.sum(gt32 * gt32) - band_a,
                "gnorm_ssq": jnp.sum(new_pn * new_pn)}

    stk = lambda t: jnp.stack([t["w1"], t["w2"]])  # noqa: E731
    ref = oracle(stk(grads), stk(params), stk(new_p),
                 new_st["buckets"][bname]["prev_norm"])
    for name, want in ref.items():
        got = taps[f"{bname}/{name}"]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=name)
    # Parseval: orthonormal haar splits grad energy across the bands
    np.testing.assert_allclose(
        float(taps[f"{bname}/band_a_ssq"] + taps[f"{bname}/band_d_ssq"]),
        float(taps[f"{bname}/grad_ssq"]), rtol=1e-5)


def test_clip_taps_track_forced_limiter_scenarios():
    """clip_rate is 0 on the first step (no history), 0 when the update
    norm shrinks, and 1 when it jumps back past gamma x prev.

    Adam normalizes per element, so the update norm tracks the number of
    ACTIVE elements (~sqrt(n)), not the gradient scale — dense -> sparse
    -> dense swings it by ~sqrt(n_elements) each way, far beyond
    gamma = 1.01."""
    opt, params, grads = _tap_setup()
    st = opt.init(params)
    upd = jax.jit(opt.tapped_update)
    sparse = jax.tree.map(
        lambda g: jnp.zeros_like(g).at[0, 0].set(1.0), grads)

    params, st, t1 = upd(grads, st, params)    # prev_norm == 0: no clip
    params, st, t2 = upd(sparse, st, params)   # norm collapses: no clip
    params, st, t3 = upd(grads, st, params)    # norm jumps back: clip all
    (bname,) = {k.split("/")[0] for k in t1}
    rates = [float(t[f"{bname}/clip_rate"]) for t in (t1, t2, t3)]
    counts = [float(t[f"{bname}/clip_count"]) for t in (t1, t2, t3)]
    assert rates == [0.0, 0.0, 1.0]
    assert counts == [0.0, 0.0, 2.0]     # two leaves in the bucket


def test_haar_approx_matches_forward_bitwise():
    g = jax.random.normal(jax.random.key(2), (3, 8, 16), jnp.float32)
    for level in (0, 1, 2, 3):
        want, _ = haar.haar_forward(g, level)
        np.testing.assert_array_equal(
            np.asarray(haar.haar_approx(g, level)), np.asarray(want))


def test_clip_flags_truth_table():
    g = 1.01
    prev = jnp.array([0.0, 1.0, 1.0, 1.0], jnp.float32)
    new = jnp.array([5.0, 1.0, 1.01, 2.0], jnp.float32)
    got = limiter.clip_flags(prev, new, g)
    # no history -> never clipped; growth below gamma -> not clipped;
    # landing on gamma x prev (what limit writes back) or above -> clipped
    assert got.tolist() == [False, False, True, True]


def test_codec_taps_match_state_recompute():
    opt, params, grads = _tap_setup(codec="int8")
    st = opt.init(params)
    _, new_st, taps = jax.jit(opt.tapped_update)(grads, st, params)
    (bname,) = {k.split("/")[0] for k in taps}
    sat = float(taps[f"{bname}/q8_sat_rate"])
    assert 0.0 <= sat <= 1.0
    # recompute eagerly from the returned encoded bucket state
    ref = _codec_taps(new_st["buckets"][bname])
    np.testing.assert_array_equal(np.asarray(taps[f"{bname}/q8_sat_rate"]),
                                  np.asarray(ref["q8_sat_rate"]))
    np.testing.assert_array_equal(np.asarray(taps[f"{bname}/q8_absmax"]),
                                  np.asarray(ref["q8_absmax"]))
    assert float(ref["q8_absmax"]) > 0.0


def test_unbucketed_engine_has_no_tap_channel():
    opt = optim.make("adam", lr=1e-2, bucketed=False)
    assert opt.tapped_update is None


# ---------------------------------------------------------------------------
# TrainLoop plumbing: boundary-sampled taps, metrics-off invariance
# ---------------------------------------------------------------------------

class _CountSource:
    """Deterministic toy data source: batch(step) == step."""

    def batch(self, step):
        return {"x": np.full((2,), step, np.float32)}


def _toy_steps():
    def step(p, s, batch):
        p = {"n": p["n"] + 1.0}
        return p, s, {"loss": jnp.sum(batch["x"]) + 0.0 * p["n"]}

    def tap_step(p, s, batch):
        p, s, m = step(p, s, batch)
        return p, s, {"loss": m["loss"], "taps": {"toy/n": p["n"]}}
    return step, tap_step


def test_trainloop_taps_sampled_at_log_boundaries_only():
    sink = MemorySink()
    obs.configure(sink=sink)
    step, tap_step = _toy_steps()
    loop = TrainLoop(step, None, _CountSource(), log_every=4, max_chunk=4,
                     log=lambda s: None, tap_step=tap_step)
    p, s, losses = loop.run({"n": jnp.float32(0)}, {}, num_steps=12)
    assert len(losses) == 12
    recs = [r for r in sink.records if r["kind"] == "train_step"]
    assert [r["step"] for r in recs] == list(range(1, 13))
    tapped = [r for r in recs if "toy/n" in r]
    # taps ride ONLY the chunk-boundary steps (1/chunk device cost)
    assert [r["step"] for r in tapped] == [4, 8, 12]
    assert [r["toy/n"] for r in tapped] == [4.0, 8.0, 12.0]


def test_trainloop_metrics_off_is_invariant_under_telemetry():
    """Same loop, no tap_step: configuring telemetry must not change a
    single computed value (records are observation, not perturbation)."""
    step, _ = _toy_steps()

    def run(with_sink):
        if with_sink:
            obs.configure(sink=MemorySink(), tracer=obs_trace.Tracer())
        else:
            obs.shutdown()
        loop = TrainLoop(step, None, _CountSource(), log_every=4,
                         max_chunk=4, log=lambda s: None)
        return loop.run({"n": jnp.float32(0)}, {}, num_steps=8)

    p0, _, l0 = run(False)
    p1, _, l1 = run(True)
    assert l0 == l1
    np.testing.assert_array_equal(np.asarray(p0["n"]), np.asarray(p1["n"]))


# ---------------------------------------------------------------------------
# Trace export: schema round-trip
# ---------------------------------------------------------------------------

def test_trace_schema_roundtrip(tmp_path):
    tr = obs_trace.Tracer(process_name="test-proc")
    with tr.span("outer", cat="train", step=3) as args:
        with tr.span("inner", cat="train", tid=1):
            pass
        args["extra"] = 7            # body-added arg lands in the event
    tr.counter("sched", cat="serve", queue_depth=2, slots_busy=1.0)
    tr.instant("admit", cat="serve", rid=0)
    path = tr.write(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    obs_trace.validate(doc)          # the round-trip IS the schema check
    evs = doc["traceEvents"]
    assert evs[0] == {"name": "process_name", "ph": "M", "pid": 0,
                      "tid": 0, "args": {"name": "test-proc"}}
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["t0_unix"] > 0
    by_name = {e["name"]: e for e in evs[1:]}
    assert by_name["outer"]["ph"] == "X"
    assert by_name["outer"]["args"] == {"step": 3, "extra": 7}
    assert by_name["inner"]["ts"] >= by_name["outer"]["ts"]
    assert by_name["inner"]["dur"] <= by_name["outer"]["dur"]
    assert by_name["sched"]["args"] == {"queue_depth": 2.0,
                                        "slots_busy": 1.0}
    assert by_name["admit"]["ph"] == "i" and by_name["admit"]["s"] == "p"
    # events come out time-sorted (Perfetto does not require it, humans
    # reading the JSON do)
    ts = [e["ts"] for e in evs[1:]]
    assert ts == sorted(ts)


def test_trace_validate_rejects_malformed():
    ok = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0, "dur": 1.0,
                           "pid": 0, "tid": 0}]}
    obs_trace.validate(ok)
    for mutate in ({"ph": "Z"}, {"ts": -1.0}, {"name": ""},
                   {"dur": None}):
        bad = {"traceEvents": [dict(ok["traceEvents"][0], **mutate)]}
        with pytest.raises(ValueError):
            obs_trace.validate(bad)
    with pytest.raises(ValueError):
        obs_trace.validate({"traceEvents": None})


# ---------------------------------------------------------------------------
# Sinks and the global registry
# ---------------------------------------------------------------------------

def test_jsonl_sink_header_provenance_and_seq(tmp_path):
    path = tmp_path / "m.jsonl"
    sink = JsonlSink(str(path), run={"cmd": "train", "arch": "x"})
    sink.emit({"kind": "train_step", "step": 1,
               "loss": jnp.float32(2.5)})   # device scalar -> json number
    sink.emit({"kind": "train_step", "step": 2, "loss": 2.25})
    sink.close()
    recs = [json.loads(l) for l in open(path)]
    assert recs[0]["kind"] == "run"
    assert recs[0]["run"] == {"cmd": "train", "arch": "x"}
    assert recs[0]["pid"] > 0
    assert [r["seq"] for r in recs] == [0, 1, 2]
    assert recs[1]["loss"] == 2.5 and "ts" in recs[1]
    # append-mode reopen: a resumed run extends the same file
    sink2 = JsonlSink(str(path), run={"cmd": "train", "resumed": True})
    sink2.emit({"kind": "train_step", "step": 3, "loss": 2.0})
    sink2.close()
    recs = [json.loads(l) for l in open(path)]
    assert len(recs) == 5 and recs[3]["run"]["resumed"] is True


def test_jsonl_lines_readable_without_close(tmp_path):
    """Flush-per-record: a SIGKILLed run keeps every completed line."""
    sink = JsonlSink(str(tmp_path / "m.jsonl"), run={})
    sink.emit({"kind": "serve_request", "rid": 0})
    recs = [json.loads(l) for l in open(tmp_path / "m.jsonl")]
    assert [r["kind"] for r in recs] == ["run", "serve_request"]
    sink.close()


def test_console_log_routes_print_and_record(capsys):
    sink = MemorySink()
    tel = Telemetry(sink=sink)
    tel.log("step 10: loss=1.2345", kind="final_loss", loss=1.2345)
    assert capsys.readouterr().out == "step 10: loss=1.2345\n"
    assert sink.records == [{"kind": "final_loss",
                             "msg": "step 10: loss=1.2345",
                             "loss": 1.2345}]


def test_null_telemetry_is_inert_default():
    obs.shutdown()
    tel = obs.get()
    assert isinstance(tel.sink, NullSink) and not tel.enabled
    tel.emit("anything", x=1)        # no guard needed at call sites
    with tel.span("nothing", steps=4):
        pass
    tel.counter("nothing", x=1)


def test_configure_metrics_dir_builds_jsonl_and_trace(tmp_path):
    d = tmp_path / "metrics"
    tel = obs.configure(str(d), run={"cmd": "t"})
    assert tel is obs.get() and tel.enabled
    tel.emit("train_step", step=1, loss=1.0)
    with tel.span("dispatch", steps=2):
        pass
    obs.shutdown()
    recs = [json.loads(l) for l in open(d / "metrics.jsonl")]
    assert [r["kind"] for r in recs] == ["run", "train_step"]
    doc = json.load(open(d / "trace.json"))
    obs_trace.validate(doc)
    assert any(e["name"] == "dispatch" for e in doc["traceEvents"])
    assert isinstance(obs.get().sink, NullSink)   # reset after shutdown


# ---------------------------------------------------------------------------
# Serve engine: per-request records emitted incrementally at retirement
# ---------------------------------------------------------------------------

def test_serve_engine_emits_request_records_at_retirement():
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, EngineConfig, Request

    sink = MemorySink()
    obs.configure(sink=sink, tracer=obs_trace.Tracer())
    cfg = configs.get_smoke("llama-60m")
    eng = Engine(cfg, lm.init(cfg, jax.random.key(0)),
                 EngineConfig(num_slots=2, page_size=8, max_ctx=16,
                              prefill_chunk=8))
    rng = np.random.RandomState(5)
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab, 6).tolist(),
                    max_gen=3) for i in range(3)]
    eng.run(reqs)
    recs = [r for r in sink.records if r["kind"] == "serve_request"]
    assert sorted(r["rid"] for r in recs) == [0, 1, 2]
    for r in recs:
        assert r["gen_tokens"] == 3 and r["prompt_tokens"] == 6
        assert 0.0 <= r["ttft_s"] <= r["latency_s"]
        assert r["done_s"] >= r["first_token_s"] >= r["admit_s"]
    # the run summary lands after every request record
    kinds = [r["kind"] for r in sink.records]
    assert kinds.index("serve_run") > max(
        i for i, k in enumerate(kinds) if k == "serve_request")
    # and the tracer saw serve-category spans + scheduler counters
    tr = obs.get().tracer
    cats = {e.get("cat") for e in tr.events}
    names = {e.get("name") for e in tr.events}
    assert "serve" in cats and {"prefill", "decode", "sched"} <= names
