"""End-to-end behaviour tests for the paper's system (replaces the
scaffold placeholder): training improves loss, GWT tracks Adam at a
fraction of state memory, and the paper's ablation axes behave as claimed.
"""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.data.pipeline import SyntheticLM
from repro.models import lm
from repro.optim.schedules import warmup_cosine

gwt_mod = importlib.import_module("repro.core.gwt")

TINY = configs.LLAMA["llama-60m"].with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=512, name="tiny")


def _train(optimizer, steps=40, seed=0, seq=64, batch=8, cfg=TINY):
    key = jax.random.key(seed)
    params = lm.init(cfg, key)
    st = optimizer.init(params)
    data = SyntheticLM(cfg.vocab, seq, batch, seed=seed)
    step_fn = jax.jit(lm.make_train_step(cfg, optimizer))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, st, m = step_fn(params, st, b)
        losses.append(float(m["loss"]))
    return losses


def test_training_reduces_loss_gwt():
    losses = _train(optim.make("gwt", lr=warmup_cosine(0.01, 40), level=2))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_gwt_tracks_adam_quality():
    """Paper Table II: GWT-2 final loss within tolerance of full Adam (tiny
    proxy: 40 steps, same schedule; paper finds GWT *beats* Adam)."""
    adam_l = _train(optim.make("adam", lr=warmup_cosine(0.0025, 40)))
    gwt_l = _train(optim.make("gwt", lr=warmup_cosine(0.01, 40), level=2))
    assert gwt_l[-1] < adam_l[-1] * 1.35, (adam_l[-1], gwt_l[-1])


def test_gwt_beats_galore_at_matched_memory():
    """Paper Table II: GWT-2 ≥ GaLore-1/4 at matched compression."""
    galore_l = _train(optim.make("galore", lr=warmup_cosine(0.01, 40),
                                 rank_frac=0.25, update_gap=20))
    gwt_l = _train(optim.make("gwt", lr=warmup_cosine(0.01, 40), level=2))
    assert gwt_l[-1] < galore_l[-1] * 1.10, (galore_l[-1], gwt_l[-1])


def test_level_sweep_memory_monotone():
    """Table XII: higher level -> strictly less optimizer memory; loss
    stays finite and in a sane band (paper: l has little quality impact)."""
    params = lm.init(TINY, jax.random.key(0))
    mems = [gwt_mod.state_memory_bytes(params, l)["total_bytes"]
            for l in (0, 1, 2, 3)]
    assert mems == sorted(mems, reverse=True)
    finals = []
    for level in (1, 3):
        l = _train(optim.make("gwt", lr=warmup_cosine(0.01, 30), level=level),
                   steps=30)
        finals.append(l[-1])
        assert np.isfinite(l).all()
    assert abs(finals[0] - finals[1]) < 0.5 * max(finals)


def test_alpha_insensitivity():
    """Fig. 6: final loss stable for alpha well above 0.1 (the paper's
    stability region; at 30 proxy steps alpha=0.1 hasn't converged yet —
    effective-lr, not instability, so we test the paper's alpha>0.1 band)."""
    finals = []
    for alpha in (0.2, 0.25, 0.4):
        l = _train(optim.make("gwt", lr=warmup_cosine(0.01, 40), level=2,
                              alpha=alpha), steps=40)
        finals.append(l[-1])
    spread = (max(finals) - min(finals)) / max(finals)
    assert spread < 0.35, finals


def test_optimizer_agnostic_hosts():
    """Fig. 4: GWT trains under Adam-mini and MUON hosts too."""
    for host in ("adam_mini", "muon"):
        l = _train(optim.make("gwt", lr=warmup_cosine(0.01, 30), level=2,
                              host=host), steps=30)
        assert l[-1] < l[0], (host, l[0], l[-1])
        assert np.isfinite(l).all()


def test_gwt_full_dimensional_update():
    """§V: unlike GaLore, the GWT update is full-dimensional — a gradient
    direction orthogonal to the approximation subspace still updates W."""
    params = {"m": {"w": jnp.zeros((8, 16))}}
    # gradient with zero block-means (pure detail): lowpass == 0
    g = jnp.tile(jnp.asarray([1.0, -1.0]), (8, 8))
    from repro.core import haar
    assert float(jnp.abs(haar.lowpass(g, 2)).max()) < 1e-6
    o = optim.make("gwt", lr=0.01, level=2, use_limiter=False)
    st = o.init(params)
    p2, _ = jax.jit(o.update)({"m": {"w": g}}, st, params)
    assert float(jnp.abs(p2["m"]["w"]).max()) > 1e-6  # details flowed through


def test_serve_generate_runs():
    from repro.launch.serve import generate
    cfg = configs.get_smoke("qwen2.5-3b")
    params = lm.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    out = generate(cfg, params, toks, gen_len=4)
    assert out.shape == (2, 4)
