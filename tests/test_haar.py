"""Unit + property tests for the Haar transform substrate (paper §III-A,
Eq. (1)-(3)) and the theory of §III-C (Theorem 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional (requirements-dev.txt); without it the shared
# conftest shim runs each property over a fixed-seed sample grid
# (endpoints + midpoint per strategy) — fewer draws, same invariants.
from conftest import given, settings, st

from repro.core import haar


def rand(key, shape):
    return jax.random.normal(jax.random.key(key), shape)


@pytest.mark.parametrize("m,n,level", [(4, 8, 1), (8, 64, 2), (16, 128, 3),
                                       (3, 256, 5), (2, 16, 4), (1, 2, 1)])
def test_reconstruction_exact(m, n, level):
    g = rand(0, (m, n))
    a, ds = haar.haar_forward(g, level)
    assert a.shape == (m, n >> level)
    assert [d.shape[-1] for d in ds] == [n >> k for k in range(level, 0, -1)]
    np.testing.assert_allclose(haar.haar_inverse(a, ds), g, atol=1e-5)


@pytest.mark.parametrize("n,level", [(8, 1), (8, 2), (64, 3), (32, 5)])
def test_matrix_equivalence_and_orthonormality(n, level):
    """Butterfly == explicit H matrix (Eq. 2/3);  H Hᵀ = I."""
    H = np.asarray(haar.haar_matrix(n, level))
    np.testing.assert_allclose(H @ H.T, np.eye(n), atol=1e-6)
    g = np.asarray(rand(1, (5, n)))
    packed = haar.haar_forward_packed(jnp.asarray(g), level)
    np.testing.assert_allclose(packed, g @ H, atol=1e-4)


def test_level0_identity():
    g = rand(2, (4, 16))
    a, ds = haar.haar_forward(g, 0)
    assert ds == []
    np.testing.assert_allclose(a, g)


def test_lowpass_is_block_mean():
    g = rand(3, (6, 32))
    pl = haar.lowpass(g, 3)
    blocks = np.asarray(g).reshape(6, 4, 8)
    expect = np.repeat(blocks.mean(-1, keepdims=True), 8, axis=-1)
    np.testing.assert_allclose(pl, expect.reshape(6, 32), atol=1e-6)


def test_approx_coeffs_are_scaled_block_means():
    """A_l = block_mean · 2^{l/2} — ties Algorithm 1 to the §III-C operator."""
    g = rand(4, (3, 64))
    level = 3
    a, _ = haar.haar_forward(g, level)
    means = np.asarray(g).reshape(3, 8, 8).mean(-1)
    np.testing.assert_allclose(a, means * 2 ** (level / 2), atol=1e-5)


def test_invalid_level_raises():
    with pytest.raises(ValueError):
        haar.haar_forward(rand(0, (2, 12)), 3)  # 12 % 8 != 0


# ---------------------------------------------------------------------------
# Property-based (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.integers(0, 4), st.integers(0, 1000))
def test_parseval_energy_preserved(m, level, seed):
    n = 16 << level
    g = rand(seed, (m, n))
    packed = haar.haar_forward_packed(g, level)
    np.testing.assert_allclose(float(jnp.linalg.norm(packed)),
                               float(jnp.linalg.norm(g)), rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 3), st.integers(0, 1000), st.floats(0.1, 10.0),
       st.floats(0.1, 10.0))
def test_linearity(level, seed, ca, cb):
    a = rand(seed, (4, 64))
    b = rand(seed + 1, (4, 64))
    lhs = haar.haar_forward_packed(ca * a + cb * b, level)
    rhs = ca * haar.haar_forward_packed(a, level) \
        + cb * haar.haar_forward_packed(b, level)
    np.testing.assert_allclose(lhs, rhs, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(0, 500))
def test_theorem1_haar_lowpass_dominance(level, seed):
    """Theorem 1: on column-smooth matrices (Assumption 1 satisfied),
    ‖G − P_l(G)‖_F < inf_{rank≤r} ‖G − X‖_F with r = n/4."""
    m = n = 64
    b = 1 << level
    rng = np.random.RandomState(seed)
    # construct a column-smooth G: slowly varying columns + tiny jitter
    base = rng.randn(m, 8) @ rng.randn(8, n)  # smooth low-dim structure
    t = np.linspace(0, 1, n)
    smooth = np.stack([np.sin(2 * np.pi * (f + 1) * t + rng.rand())
                       for f in range(m)])
    G = base * 0.1 + smooth + 0.5 * rng.randn(m, 1)  # row offsets (flat cols)
    r = n // 4
    sv = np.linalg.svd(G, compute_uv=False)
    dG = np.diff(G, axis=1)
    lhs_cond = np.linalg.norm(dG)
    rhs_cond = np.sin(np.pi / b) * np.sqrt(r) * sv[r]
    if lhs_cond >= rhs_cond:
        return  # Assumption 1 not satisfied for this draw — vacuous case
    err_haar = np.linalg.norm(G - np.asarray(haar.lowpass(jnp.asarray(G),
                                                          level)))
    err_rank = np.sqrt((sv[r:] ** 2).sum())
    assert err_haar < err_rank + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 4), st.integers(0, 100))
def test_detail_scale_upsample_consistency(level, seed):
    """Multi-level detail normalization == explicit per-band block repeat."""
    scale = jnp.abs(rand(seed, (3, 8))) + 0.1  # A_l resolution (n=8·2^level)
    for k in range(1, level + 1):
        up = haar.detail_scale_upsample(scale, level, k)
        assert up.shape[-1] == 8 * (1 << (level - k))
        np.testing.assert_allclose(
            up, np.repeat(np.asarray(scale), 1 << (level - k), axis=-1))


# ---------------------------------------------------------------------------
# db2 (Daubechies-4) — beyond-paper wavelet option
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,level", [(4, 32, 1), (8, 64, 2), (3, 128, 3)])
def test_db2_reconstruction_and_parseval(m, n, level):
    g = rand(7, (m, n))
    a, ds = haar.db2_forward(g, level)
    assert a.shape == (m, n >> level)
    rec = haar.db2_inverse(a, ds)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(g), atol=1e-5)
    e_in = float(jnp.sum(g ** 2))
    e_out = float(jnp.sum(a ** 2) + sum(jnp.sum(d ** 2) for d in ds))
    np.testing.assert_allclose(e_in, e_out, rtol=1e-5)


def test_db2_smoother_on_smooth_signals():
    """db2 concentrates more energy in the approximation band than Haar on
    smooth signals (its raison d'être as a beyond-paper option)."""
    t = np.linspace(0, 4 * np.pi, 256)
    g = jnp.asarray(np.sin(t)[None, :].repeat(4, 0), jnp.float32)
    a_h, _ = haar.haar_forward(g, 3)
    a_d, _ = haar.db2_forward(g, 3)
    e = float(jnp.sum(g ** 2))
    frac_h = float(jnp.sum(a_h ** 2)) / e
    frac_d = float(jnp.sum(a_d ** 2)) / e
    assert frac_d >= frac_h - 1e-3, (frac_h, frac_d)


@pytest.mark.parametrize("fwd,inv", [
    (haar.haar_forward, haar.haar_inverse),
    (haar.db2_forward, haar.db2_inverse),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_forward_preserves_dtype_both_wavelets(fwd, inv, dtype):
    """A bf16 ``state_dtype`` host must see the same band dtypes under
    either wavelet: db2 historically upcast to f32 (f32 taps + an explicit
    astype) while Haar stayed in the input dtype, so switching wavelets
    silently doubled the moment footprint."""
    g = rand(11, (8, 64)).astype(dtype)
    a, ds = fwd(g, 2)
    assert a.dtype == dtype, (fwd.__name__, a.dtype)
    assert all(d.dtype == dtype for d in ds)
    assert inv(a, ds).dtype == dtype


def test_gwt_db2_optimizer_trains():
    import jax as _jax
    from repro import optim
    def loss_fn(params):
        return sum(jnp.sum((l - 0.5) ** 2) for l in _jax.tree.leaves(params))
    from repro.optim.schedules import warmup_cosine
    o = optim.make("gwt", lr=warmup_cosine(0.05, 40), level=2, wavelet="db2")
    ps = {"mlp": {"w1": rand(3, (16, 32))}}
    st = o.init(ps)
    l0 = float(loss_fn(ps))
    upd = _jax.jit(o.update)
    for _ in range(40):
        ps, st = upd(_jax.grad(loss_fn)(ps), st, ps)
    assert float(loss_fn(ps)) < 0.9 * l0
