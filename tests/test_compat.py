"""The runtime portability layer: both shim branches (native API present
vs. fallback) via monkeypatching, kernel-backend resolution, MeshContext,
plus regressions that (a) every src/repro module imports under the pinned
JAX and (b) no module outside repro.compat touches the drifting jax
symbols directly."""

import contextlib
import importlib
import os
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.runtime.context import MeshContext

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src"


# ---------------------------------------------------------------------------
# make_mesh / AxisType
# ---------------------------------------------------------------------------

def test_make_mesh_single_device():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert tuple(mesh.axis_names) == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_axis_type_symbols_exist():
    assert hasattr(compat.AxisType, "Auto")
    assert len(compat.auto_axis_types(3)) == 3


def test_make_mesh_axis_types_feature_detection(monkeypatch):
    rec = {}

    def fake(shapes, names, **kw):
        rec.clear()
        rec.update(kw, args=(shapes, names))
        return "MESH"

    monkeypatch.setattr(compat, "_NATIVE_MAKE_MESH", fake)
    monkeypatch.setattr(compat, "_MAKE_MESH_AXIS_TYPES", True)
    assert compat.make_mesh((2,), ("data",)) == "MESH"
    assert rec["axis_types"] == compat.auto_axis_types(1)

    monkeypatch.setattr(compat, "_MAKE_MESH_AXIS_TYPES", False)
    compat.make_mesh((2,), ("data",))
    assert "axis_types" not in rec  # older signature: kwarg dropped


def test_make_mesh_without_native_make_mesh(monkeypatch):
    monkeypatch.setattr(compat, "_NATIVE_MAKE_MESH", None)
    mesh = compat.make_mesh((1,), ("data",))
    assert tuple(mesh.axis_names) == ("data",)


# ---------------------------------------------------------------------------
# ambient mesh: use_mesh / get_abstract_mesh, both branches
# ---------------------------------------------------------------------------

def test_ambient_mesh_none_by_default():
    assert compat.get_abstract_mesh() is None


def test_use_mesh_sets_ambient_and_restores():
    mesh = compat.make_mesh((1,), ("data",))
    with compat.use_mesh(mesh):
        m = compat.get_abstract_mesh()
        assert m is not None and "data" in tuple(m.axis_names)
    assert compat.get_abstract_mesh() is None


def test_use_mesh_none_is_noop():
    with compat.use_mesh(None) as m:
        assert m is None
    assert compat.get_abstract_mesh() is None


def test_fallback_branch_forced(monkeypatch):
    """Force the pre-0.5 path: thread-local stack + Mesh context manager."""
    monkeypatch.setattr(compat, "_NATIVE_GET_ABSTRACT_MESH", None)
    monkeypatch.setattr(compat, "_NATIVE_USE_MESH", None)
    mesh = compat.make_mesh((1,), ("data",))
    assert compat.get_abstract_mesh() is None
    with compat.use_mesh(mesh):
        assert compat.get_abstract_mesh() is mesh
        with compat.use_mesh(mesh):  # nesting
            assert compat.get_abstract_mesh() is mesh
        assert compat.get_abstract_mesh() is mesh
    assert compat.get_abstract_mesh() is None


def test_native_branch_forced(monkeypatch):
    """Force the post-0.5 path with stand-ins for the native API."""
    mesh = compat.make_mesh((1,), ("data",))
    monkeypatch.setattr(compat, "_NATIVE_GET_ABSTRACT_MESH", lambda: mesh)
    assert compat.get_abstract_mesh() is mesh

    calls = []

    @contextlib.contextmanager
    def fake_use(m):
        calls.append(m)
        yield

    monkeypatch.setattr(compat, "_NATIVE_USE_MESH", fake_use)
    with compat.use_mesh(mesh):
        pass
    assert calls == [mesh]


def test_native_empty_abstract_mesh_normalized(monkeypatch):
    class _Empty:
        axis_names = ()

    monkeypatch.setattr(compat, "_NATIVE_GET_ABSTRACT_MESH", _Empty)
    assert compat.get_abstract_mesh() is None


# ---------------------------------------------------------------------------
# with_sharding_constraint
# ---------------------------------------------------------------------------

def test_wsc_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = compat.with_sharding_constraint(x, "data", None)
    assert y is x


def test_wsc_resolves_under_concrete_mesh():
    mesh = compat.make_mesh((1,), ("data",))

    @jax.jit
    def f(x):
        return compat.with_sharding_constraint(x, "data", None, mesh=mesh)

    y = f(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(y), np.ones((4, 4)))


# ---------------------------------------------------------------------------
# kernel backend selection
# ---------------------------------------------------------------------------

def test_resolve_kernel_impl_auto_cpu():
    assert compat.resolve_kernel_impl("auto", platform="cpu") == "jnp"
    assert compat.resolve_kernel_impl(None, platform="tpu") == "pallas"
    assert compat.resolve_kernel_impl("interpret") == "interpret"


def test_resolve_kernel_impl_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    assert compat.resolve_kernel_impl("auto", platform="tpu") == "interpret"
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "auto")
    assert compat.resolve_kernel_impl("auto", platform="cpu") == "jnp"


def test_env_override_typo_fails_fast(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "cuda")
    with pytest.raises(ValueError, match="REPRO_KERNEL_IMPL"):
        compat.resolve_kernel_impl("auto")


def test_kernel_impl_env_not_frozen_by_trace_cache(monkeypatch):
    """'auto' must re-resolve per call: resolving inside a jitted body with
    impl static would freeze the env read into the first trace."""
    from repro.kernels.haar_dwt import kernel as dkern, ops as dops
    g = jnp.ones((4, 8), jnp.float32)
    a1 = dops.dwt(g, 1)  # traces the platform default (jnp on CPU)

    seen = {}
    real = dkern.haar_dwt_fwd

    def spy(*a, **kw):
        seen["interpret"] = kw.get("interpret", False)
        return real(*a, **kw)

    monkeypatch.setattr(dkern, "haar_dwt_fwd", spy)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    a2 = dops.dwt(g, 1)  # must take the interpret path NOW
    assert seen.get("interpret") is True
    np.testing.assert_allclose(np.asarray(a1[0]), np.asarray(a2[0]),
                               atol=1e-5)


def test_unwrap_mesh_accepts_mesh_context_or_mesh():
    mesh = compat.make_mesh((1,), ("data",))
    assert compat.unwrap_mesh(mesh) is mesh
    assert compat.unwrap_mesh(MeshContext.create(mesh=mesh)) is mesh
    assert compat.unwrap_mesh(None) is None


def test_resolve_kernel_impl_invalid():
    with pytest.raises(ValueError):
        compat.resolve_kernel_impl("cuda")


# ---------------------------------------------------------------------------
# MeshContext
# ---------------------------------------------------------------------------

def test_mesh_context_single_device_defaults():
    ctx = MeshContext.create()
    assert ctx.mesh is None and ctx.axis_names == ()
    assert ctx.axis_size("data") == 0
    assert ctx.dp_axes(16) is None
    x = jnp.ones((2, 2))
    assert ctx.constrain(x, "data") is x  # no mesh -> no-op


def test_mesh_context_dp_axes_and_sizes():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    ctx = MeshContext.create(mesh=mesh)
    assert ctx.has_axis("model") and ctx.axis_size("data") == 1
    assert ctx.dp_axes(4) == "data"


def test_mesh_context_ambient_adopts_use_mesh():
    mesh = compat.make_mesh((1,), ("data",))
    with compat.use_mesh(mesh):
        ctx = MeshContext.ambient()
        assert ctx.axis_names == ("data",)
    assert MeshContext.ambient().mesh is None


def test_mesh_context_activate_roundtrip():
    mesh = compat.make_mesh((1,), ("data",))
    ctx = MeshContext.create(mesh=mesh)
    with ctx.activate():
        assert compat.get_abstract_mesh() is not None
    assert compat.get_abstract_mesh() is None


# ---------------------------------------------------------------------------
# regressions
# ---------------------------------------------------------------------------

def _all_repro_modules():
    return sorted(
        ".".join(p.relative_to(SRC).with_suffix("").parts)
        for p in SRC.rglob("*.py") if p.name != "__init__.py")


@pytest.mark.parametrize("mod", _all_repro_modules())
def test_every_module_imports_under_pinned_jax(mod):
    """The original bug class: post-0.5-only jax attribute access at import
    or call time.  Every module must import cleanly on the pinned JAX."""
    xla_flags = os.environ.get("XLA_FLAGS")
    try:
        importlib.import_module(mod)
    finally:  # launch.dryrun guards its XLA_FLAGS write; belt-and-braces
        if xla_flags is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = xla_flags


def test_no_direct_mesh_api_references():
    """Grep-clean: the drifting symbols appear only inside repro/compat.py
    (and this test, which assembles the pattern from fragments)."""
    pat = re.compile("|".join(
        "jax" + re.escape(".") + frag
        for frag in ("sharding.get_abstract_mesh", "sharding.AxisType",
                     "make_mesh", "set_mesh", "sharding.use_mesh")))
    offenders = []
    for sub in ("src", "tests", "benchmarks", "examples", "scripts"):
        base = REPO / sub
        if not base.exists():
            continue
        for p in base.rglob("*.py"):
            if p.name in ("compat.py", "test_compat.py"):
                continue
            if pat.search(p.read_text()):
                offenders.append(str(p.relative_to(REPO)))
    assert not offenders, offenders
