"""Sharded multi-device train path (DESIGN.md §3, wired): simulated-mesh
equivalence tiers in subprocess isolation.

* **Topology equivalence (exact reduce)** — a 1-device run (`--mesh 1
  --accum 8`) and an 8-device run (`--mesh 8`) of the SAME logical shard
  grid produce byte-identical final checkpoints: per-shard grads are
  bitwise reproducible across batch sizes (row-independent forward math),
  the accumulation scan sums shards sequentially, and the CPU backend's
  ``psum`` reduces in device order — the same order.  Donation must be
  off for THIS tier only: ``donate_argnums`` changes XLA fusion (and
  hence float rounding) differently per topology.
* **Compressed reduce** — same trajectory within the detail-band
  quantization tolerance, under the full production config (donation,
  FSDP param/state sharding, wavelet-compressed wire).
* **Preempt/resume on a mesh** — SIGTERM → checkpoint → ``--resume`` is
  bitwise against the uninterrupted run with sharding + donation +
  compression all on (same-topology donation IS deterministic).
* **Cross-topology resume** — a checkpoint saved by the 1-device run
  continues on the 8-device mesh (and vice versa) bit-for-bit.
* **psum ≡ emulated sequential sum** — anchors the in-process property
  tests (tests/test_distributed.py) that drive
  ``compression.emulated_mean`` instead of a real mesh.
"""

import os
import re
import shutil
import signal
import subprocess
import sys
import time

import pytest

from conftest import device_env, run_in_devices

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BASE = ["--arch", "llama-60m", "--smoke", "--optimizer", "gwt", "--level",
        "2", "--lr", "0.01", "--steps", "24", "--batch", "16", "--seq",
        "32", "--log-every", "4", "--ckpt-every", "8"]
EXACT_1DEV = ["--mesh", "1", "--accum", "8", "--dp-reduce", "exact",
              "--shard-params", "none", "--no-donate"]
EXACT_8DEV = ["--mesh", "8", "--dp-reduce", "exact",
              "--shard-params", "none", "--no-donate"]
# full production surface: donated, FSDP-sharded state, compressed wire
PROD_8DEV = ["--mesh", "8", "--dp-reduce", "compressed", "--dp-level", "2",
             "--shard-params", "auto"]


def _launch(ckpt_dir, n_devices, extra=(), wait=True, timeout=600):
    cmd = [sys.executable, "-m", "repro.launch.train", *BASE,
           "--ckpt-dir", str(ckpt_dir), *extra]
    proc = subprocess.Popen(cmd, cwd=REPO, env=device_env(n_devices),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    if not wait:
        return proc
    out, err = proc.communicate(timeout=timeout)
    assert proc.returncode == 0, out + err
    return out + err


def _blobs(ckpt_dir, step=24):
    """{filename: bytes} of every leaf in the committed checkpoint."""
    d = os.path.join(str(ckpt_dir), f"step_{step:09d}")
    assert os.path.exists(os.path.join(d, "COMMITTED")), \
        os.listdir(str(ckpt_dir))
    out = {}
    for name in sorted(os.listdir(d)):
        if name.endswith(".bin"):
            with open(os.path.join(d, name), "rb") as f:
                out[name] = f.read()
    return out


def _losses(log: str):
    return [float(m) for m in re.findall(r"step \d+: loss=([\d.]+)", log)]


def _assert_blobs_equal(a, b, tag):
    assert a.keys() == b.keys()
    diff = [n for n in a if a[n] != b[n]]
    assert not diff, f"{tag}: {len(diff)} leaves differ: {diff[:6]}"


@pytest.fixture(scope="module")
def topo(tmp_path_factory):
    """The three shared launcher runs: 1-dev exact, 8-dev exact (same
    logical shard grid), 8-dev production (donated FSDP compressed)."""
    root = tmp_path_factory.mktemp("sharded")
    dirs = {"one": root / "one", "eight": root / "eight",
            "prod": root / "prod"}
    logs = {"one": _launch(dirs["one"], 1, EXACT_1DEV),
            "eight": _launch(dirs["eight"], 8, EXACT_8DEV),
            "prod": _launch(dirs["prod"], 8, PROD_8DEV)}
    return {"dirs": dirs, "logs": logs}


# ---------------------------------------------------------------------------
# Tier 1: topology equivalence
# ---------------------------------------------------------------------------

def test_exact_reduce_topology_bitwise(topo):
    """8-device exact-reduce ≡ 1-device, bitwise, through params AND
    optimizer state: the logical shard grid (16 rows → 8 contiguous
    shards) is what defines the numerics, not the device count."""
    _assert_blobs_equal(_blobs(topo["dirs"]["one"]),
                        _blobs(topo["dirs"]["eight"]), "1dev vs 8dev")


def test_exact_reduce_loss_streams_identical(topo):
    l1, l8 = _losses(topo["logs"]["one"]), _losses(topo["logs"]["eight"])
    assert len(l1) == len(l8) == 6          # 24 steps / log_every 4
    assert l1 == l8                          # printed at 4 decimals


def test_mesh_wire_accounting_logged(topo):
    """The launcher reports the per-step DP wire bytes; the compressed
    production run must claim a real saving over exact f32."""
    m = re.search(r"dp_reduce=compressed dp=8 wire=([\d.]+)MiB/step vs "
                  r"exact ([\d.]+)MiB \(([\d.]+)x\)", topo["logs"]["prod"])
    assert m, topo["logs"]["prod"]
    assert float(m.group(3)) > 1.3           # bf16 smoke model ratio


# ---------------------------------------------------------------------------
# Tier 2: compressed reduction — bounded deviation
# ---------------------------------------------------------------------------

def test_compressed_reduce_loss_within_tolerance(topo):
    """The production run (compressed wire, FSDP, donation) tracks the
    exact-reduce trajectory within the documented band: bf16 detail
    quantization perturbs each step ~1e-3 relative, compounding to a few
    percent over 24 GWT steps on the smoke config."""
    exact = _losses(topo["logs"]["eight"])
    comp = _losses(topo["logs"]["prod"])
    assert len(exact) == len(comp) == 6
    for i, (e, c) in enumerate(zip(exact, comp)):
        assert abs(e - c) / e < 0.10, (i, e, c)


# ---------------------------------------------------------------------------
# Tier 3: preempt → resume on a mesh, full production config
# ---------------------------------------------------------------------------

def test_mesh_sigterm_resume_bitwise(topo, tmp_path):
    """SIGTERM a donated+sharded+compressed 8-device run mid-training,
    --resume, and require the final checkpoint byte-identical to the
    uninterrupted production run: the absolute chunk grid and the
    restored per-bucket state survive sharding."""
    d = tmp_path / "interrupted"
    proc = _launch(d, 8, PROD_8DEV, wait=False)
    first_ckpt = os.path.join(str(d), "step_000000008", "COMMITTED")
    deadline = time.time() + 570
    while time.time() < deadline and proc.poll() is None \
            and not os.path.exists(first_ckpt):
        time.sleep(0.05)
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
    out, err = proc.communicate(timeout=120)
    assert proc.returncode == 0, out + err

    finished = os.path.exists(
        os.path.join(str(d), "step_000000024", "COMMITTED"))
    log = _launch(d, 8, [*PROD_8DEV, "--resume"])
    if not finished:
        assert "resumed from step" in log, log
    _assert_blobs_equal(_blobs(d), _blobs(topo["dirs"]["prod"]),
                        "mesh sigterm+resume")


# ---------------------------------------------------------------------------
# Tier 4: cross-topology checkpoint restore (satellite)
# ---------------------------------------------------------------------------

def _resume_from(src_dir, dst, drop_step=24):
    shutil.copytree(str(src_dir), str(dst))
    shutil.rmtree(os.path.join(str(dst), f"step_{drop_step:09d}"))


def test_checkpoint_saved_1dev_resumes_on_mesh_bitwise(topo, tmp_path):
    """Save on 1 device, --resume on the 8-device mesh: path-keyed bucket
    state restores under the mesh NamedShardings without migration, and —
    because the logical shard grid is topology-free — the continued run
    lands byte-identical to the straight 8-device run."""
    d = tmp_path / "to8"
    _resume_from(topo["dirs"]["one"], d)
    log = _launch(d, 8, [*EXACT_8DEV, "--resume"])
    assert "resumed from step 16" in log, log
    _assert_blobs_equal(_blobs(d), _blobs(topo["dirs"]["eight"]),
                        "1dev ckpt → 8dev mesh")


def test_checkpoint_saved_on_mesh_resumes_1dev_bitwise(topo, tmp_path):
    """...and the reverse: a mesh-written checkpoint continues on a single
    device bit-for-bit."""
    d = tmp_path / "to1"
    _resume_from(topo["dirs"]["eight"], d)
    log = _launch(d, 1, [*EXACT_1DEV, "--resume"])
    assert "resumed from step 16" in log, log
    _assert_blobs_equal(_blobs(d), _blobs(topo["dirs"]["one"]),
                        "8dev ckpt → 1dev")


def test_fsdp_state_restores_under_different_mesh(tmp_path):
    """FSDP-sharded optimizer state saved on an 8-way mesh restores onto a
    4-way mesh (different NamedShardings, same path-keyed buckets) with no
    migration step."""
    d = tmp_path / "fsdp"
    _launch(d, 8, [*PROD_8DEV, "--steps", "8"])
    log = _launch(d, 8, ["--mesh", "4", "--dp-reduce", "compressed",
                         "--shard-params", "auto", "--steps", "12",
                         "--resume"])
    assert "resumed from step 8" in log, log
    assert _blobs(d, step=12)


# ---------------------------------------------------------------------------
# Tier 5: donation stays single-buffered under sharding
# ---------------------------------------------------------------------------

def test_donation_single_buffered_under_sharding():
    """XLA buffer assignment of the mesh-aware step: donating
    (params, opt_state) must still alias them through when they are
    FSDP-sharded and the gradient reduction runs inside shard_map."""
    code = """
    import jax, jax.numpy as jnp
    from repro import compat, configs, optim
    from repro.models import lm
    from repro.data.pipeline import SyntheticLM
    from repro.runtime.context import MeshContext
    from repro.distributed import sharding as shr
    from repro.optim.engine import live_update_bytes

    cfg = configs.get_smoke("llama-60m")
    mesh = compat.make_mesh((8,), ("data",))
    ctx = MeshContext.create(mesh=mesh)
    data = SyntheticLM(cfg.vocab, 32, 16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    batch_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in batch.items()}
    sh = shr.train_step_shardings(cfg, lm, batch_abs, mesh,
                                  shard_params=True)
    opt = optim.make("gwt", lr=1e-2, level=2,
                     state_shardings=sh.opt["buckets"])
    params = jax.device_put(lm.init(cfg, jax.random.key(0)), sh.params)
    st = opt.init(params)
    with ctx.activate():
        plain = jax.jit(lm.make_train_step(
            cfg, opt, ctx=ctx, dp_reduce="compressed", shardings=sh)) \
            .lower(params, st, batch).compile()
        donated = lm.make_train_step(
            cfg, opt, ctx=ctx, dp_reduce="compressed", shardings=sh,
            donate=True).lower(params, st, batch).compile()
    lp, ld = live_update_bytes(plain), live_update_bytes(donated)
    assert lp is not None and ld is not None
    assert ld < lp, (ld, lp)
    ma = donated.memory_analysis()
    assert ma.alias_size_in_bytes > 0
    print("DONATION_OK", lp, ld)
    """
    r = run_in_devices(8, code)
    assert "DONATION_OK" in r.stdout, r.stdout + r.stderr


def test_dp_reduce_rejects_tp_meshes():
    """Leaving a 'model' axis to GSPMD inside the manual DP region
    miscompiles on the pinned jax/XLA (hard IsManualSubgroup abort), so
    the step builder must refuse TP meshes with a real error instead."""
    from repro import compat, configs, optim
    from repro.models import lm
    from repro.runtime.context import MeshContext

    cfg = configs.get_smoke("llama-60m")
    ctx = MeshContext.create(mesh=compat.make_mesh((1, 1),
                                                   ("data", "model")))
    opt = optim.make("gwt", lr=1e-2, level=2)
    with pytest.raises(ValueError, match="pure-DP mesh"):
        lm.make_train_step(cfg, opt, ctx=ctx, dp_reduce="exact")
    with pytest.raises(ValueError, match="'data' axis"):
        lm.make_train_step(cfg, opt, ctx=MeshContext.create(),
                           dp_reduce="exact")
    # the string 'none' routes to the plain auto-sharded step, not a crash
    step = lm.make_train_step(cfg, opt, ctx=MeshContext.create(),
                              dp_reduce="none")
    assert callable(step)


# ---------------------------------------------------------------------------
# Tier 6: the reduction-order anchor for the in-process property tests
# ---------------------------------------------------------------------------

def test_psum_matches_emulated_sequential_sum():
    """``compressed_psum_mean`` on a real 8-device axis is bitwise equal
    to ``compression.emulated_mean`` (sequential worker-order sum) for
    the exact and bf16 modes — licensing the hypothesis properties in
    test_distributed.py to run meshless.  f8 payloads match within one
    detail ulp: the backend's f8 all-reduce accumulation strategy is
    buffer-size-dependent (bitwise contracts ride the exact mode only)."""
    code = """
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.distributed import compression

    mesh = compat.make_mesh((8,), ("data",))
    for shape, level, dtype, tag in [
            ((8, 16, 64), 2, None, "exact"),
            ((8, 16, 64), 2, jnp.bfloat16, "bf16"),
            ((8, 16, 64), 3, jnp.float8_e4m3fn, "f8"),
            ((8, 32), 2, jnp.bfloat16, "1d_divisible_compresses"),
            ((8, 33), 2, jnp.bfloat16, "fallback_1d"),
            ((8, 4, 30), 2, jnp.bfloat16, "fallback_odd")]:
        g = jax.random.normal(jax.random.key(0), shape, jnp.float32) * 2.3
        fn = compat.shard_map(
            functools.partial(compression.compressed_psum_mean,
                              axis_name="data", level=level,
                              detail_dtype=dtype),
            mesh, in_specs=P("data"), out_specs=P("data"))
        with compat.use_mesh(mesh):
            out = np.asarray(jax.jit(fn)(g))[0]
        ref = np.asarray(compression.emulated_mean(g, level, dtype))
        if tag == "f8":
            ulp = float(jnp.finfo(dtype).eps) * np.abs(ref).max()
            assert np.abs(out - ref).max() <= ulp, \\
                (tag, np.abs(out - ref).max(), ulp)
        else:
            assert np.array_equal(out, ref), (tag, np.abs(out - ref).max())
    print("PSUM_EMULATION_OK")
    """
    r = run_in_devices(8, code)
    assert "PSUM_EMULATION_OK" in r.stdout, r.stdout + r.stderr
