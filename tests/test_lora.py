"""LoRA fine-tune path: frozen base, adapter-only optimizer state, and
the launcher-level pre-train → checkpoint → fine-tune round trip."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.checkpoint.manager import CheckpointManager
from repro.models import lm, lora
from repro.optim.engine import state_bytes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RANK, ALPHA = 4, 8.0


def _cfg():
    return configs.LLAMA["llama-60m"].with_(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=64)


def _batch(cfg, seed=0, B=2, S=16):
    toks = jax.random.randint(jax.random.key(seed), (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def test_inject_merge_identity_at_init():
    """b starts at zero, so merge(inject(p)) == p bitwise — a LoRA run
    begins exactly at the restored base model."""
    cfg = _cfg()
    params = lm.init(cfg, jax.random.key(0))
    tree = lora.inject(params, RANK, jax.random.key(7))
    merged = lora.merge(tree, ALPHA, RANK)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # adapters exist exactly for the target projections
    apaths = {p for p, _ in zip(*__import__(
        "repro.optim.base", fromlist=["flatten_with_paths"]
    ).flatten_with_paths(tree["lora"])[:2])}
    assert apaths  # non-empty
    assert all(p.rsplit("/", 2)[-2] in lora.LORA_TARGETS or
               p.rsplit("/", 1)[-1] in ("a", "b") for p in apaths)


def test_inject_deterministic_in_key():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.key(0))
    t1 = lora.inject(params, RANK, jax.random.key(7))
    t2 = lora.inject(params, RANK, jax.random.key(7))
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_training_moves_adapters_only_and_state_is_adapter_sized():
    """Two real-gradient steps: base bitwise-frozen, adapters move, and
    ``state_bytes`` counts EXACTLY the adapter moments (adam inner: m+v
    f32 per adapter element, plus the step counter)."""
    cfg = _cfg()
    params = lm.init(cfg, jax.random.key(0))
    tree = lora.inject(params, RANK, jax.random.key(7))
    opt = lora.wrap_optimizer(optim.make("adam", lr=0.01))
    st = opt.init(tree)

    n_adapter = sum(l.size for l in jax.tree.leaves(tree["lora"]))
    assert state_bytes(opt, tree) == 2 * n_adapter * 4 + 4

    step = jax.jit(lora.make_train_step(lm, cfg, opt, rank=RANK,
                                        alpha=ALPHA))
    t, s = tree, st
    for i in range(2):
        t, s, m = step(t, s, _batch(cfg, seed=i))
    for a, b in zip(jax.tree.leaves(t["base"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    moved = [not np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(t["lora"]),
                             jax.tree.leaves(tree["lora"]))]
    assert any(moved)
    assert float(m["loss"]) > 0.0


def test_lora_composes_with_gwt_and_int8():
    """The adapters' moments go through the wavelet rule + int8 codec —
    state must be strictly smaller than raw-adam-on-adapters."""
    cfg = _cfg()
    params = lm.init(cfg, jax.random.key(0))
    tree = lora.inject(params, 8, jax.random.key(7))  # rank 8: divisible
    adam_bytes = state_bytes(lora.wrap_optimizer(optim.make("adam",
                                                            lr=0.01)), tree)
    gwt8_bytes = state_bytes(lora.wrap_optimizer(
        optim.make("gwt", lr=0.01, level=2, state_codec="int8")), tree)
    assert gwt8_bytes < adam_bytes
    opt = lora.wrap_optimizer(optim.make("gwt", lr=0.01, level=2,
                                         state_codec="int8"))
    step = jax.jit(lora.make_train_step(lm, cfg, opt, rank=8, alpha=ALPHA))
    t, s, m = step(tree, opt.init(tree), _batch(cfg))
    assert np.isfinite(float(m["loss"]))


def test_wrap_optimizer_requires_engine():
    from repro.optim.base import Optimizer
    with pytest.raises(ValueError, match="engine"):
        lora.wrap_optimizer(Optimizer(lambda p: {}, lambda g, s, p: (p, s)))


# ---------------------------------------------------------------------------
# Launcher-level: pre-train → checkpoint → `--finetune lora --base-ckpt`
# → the frozen base must equal the pre-trained weights bitwise across the
# whole fine-tune run, and the fine-tune checkpoint must restore.
# ---------------------------------------------------------------------------

def _run(args, timeout=600):
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-m", "repro.launch.train", *args],
                       cwd=REPO, env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout + r.stderr


def test_launcher_pretrain_then_lora_finetune(tmp_path):
    base_dir, ft_dir = str(tmp_path / "base"), str(tmp_path / "ft")
    common = ["--arch", "llama-60m", "--smoke", "--lr", "0.01",
              "--batch", "2", "--seq", "32", "--log-every", "4"]
    _run([*common, "--optimizer", "adam", "--steps", "6",
          "--ckpt-dir", base_dir, "--ckpt-every", "6"])
    log = _run([*common, "--optimizer", "gwt", "--level", "2",
                "--finetune", "lora", "--lora-rank", "8",
                "--base-ckpt", base_dir, "--steps", "6",
                "--ckpt-dir", ft_dir, "--ckpt-every", "6", "--seed", "0"])
    assert "restored pre-trained base" in log
    assert "finetune=lora" in log

    # reconstruct the like-trees in-process to read both checkpoints
    cfg = configs.get_smoke("llama-60m")
    params = lm.init(cfg, jax.random.key(0))
    base_params, base_step = CheckpointManager(base_dir).restore_params(
        None, params)
    assert base_step == 6
    like_tree = lora.inject(base_params, 8,
                            jax.random.fold_in(jax.random.key(0), 777))
    ft_tree, ft_step = CheckpointManager(ft_dir).restore_params(
        None, like_tree)
    assert ft_step == 6
    # base bitwise-frozen across the fine-tune run
    for a, b in zip(jax.tree.leaves(ft_tree["base"]),
                    jax.tree.leaves(base_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # adapters trained: at least one `b` leaf left zero-init
    moved = [not np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(ft_tree["lora"]),
                             jax.tree.leaves(like_tree["lora"]))]
    assert any(moved)


def test_launcher_rejects_lora_with_dp_reduce():
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "llama-60m",
         "--smoke", "--finetune", "lora", "--dp-reduce", "exact",
         "--steps", "1"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode != 0
    assert "--finetune lora does not compose with --dp-reduce" in r.stderr
