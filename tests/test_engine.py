"""Leaf-plan bucketed engine: equivalence vs the per-leaf reference,
bucket grouping, legacy-checkpoint migration, and trace-size sublinearity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.optim import engine


def layered_params(n_layers=4, d=16, f=32, vocab=10):
    """≥4-layer smoke model: per-layer attn/mlp leaves + embed/norm."""
    k = jax.random.key(0)
    p = {"embed": jax.random.normal(jax.random.fold_in(k, 99), (vocab, d)),
         "norm": jnp.ones((d,))}
    for i in range(n_layers):
        kk = jax.random.fold_in(k, i)
        p[f"layer_{i}"] = {
            "attn": {"wq": jax.random.normal(jax.random.fold_in(kk, 0),
                                             (d, d)) * 0.1,
                     "wo": jax.random.normal(jax.random.fold_in(kk, 1),
                                             (d, d)) * 0.1},
            "mlp": {"w1": jax.random.normal(jax.random.fold_in(kk, 2),
                                            (d, f)) * 0.1,
                    "w2": jax.random.normal(jax.random.fold_in(kk, 3),
                                            (f, d)) * 0.1}}
    return p


def run_steps(opt, params, steps=3):
    st = opt.init(params)
    upd = jax.jit(opt.update)
    p = params
    for i in range(steps):
        g = jax.tree.map(lambda x: x * 0.01 + 0.001 * (i + 1), params)
        p, st = upd(g, st, p)
    return p, st


CASES = [
    ("adam", {}), ("adam_mini", {}), ("muon", {}), ("sgd", {}),
    ("galore", {"rank": 4, "update_gap": 2}),
    ("apollo", {"rank": 4, "update_gap": 2}),
    ("fira", {"rank": 4, "update_gap": 2}),
    ("gwt", {"level": 2}),
    ("gwt", {"level": 1, "host": "adam_mini"}),
    ("gwt", {"level": 2, "host": "muon"}),
    ("gwt", {"level": 2, "wavelet": "db2"}),
    ("gwt", {"level": 2, "impl": "interpret"}),  # fused vector_update path
]


@pytest.mark.parametrize("name,kw", CASES)
def test_bucketed_matches_per_leaf_reference(name, kw):
    """One scan/fused call per bucket == unrolled per-leaf loop.

    Bitwise for every family except GWT, where XLA fuses the Haar
    butterfly differently inside the scan body (≤1 f32 ulp observed)."""
    params = layered_params()
    pb, sb = run_steps(optim.make(name, lr=0.01, **kw), params)
    pu, su = run_steps(optim.make(name, lr=0.01, bucketed=False, **kw),
                       params)
    assert (jax.tree_util.tree_structure(sb)
            == jax.tree_util.tree_structure(su))
    tol = {} if name != "gwt" else {"atol": 1e-6, "rtol": 1e-6}
    for a, b in zip(jax.tree.leaves(pb), jax.tree.leaves(pu)):
        if tol:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(sb), jax.tree.leaves(su)):
        if tol:
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), **tol)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_groups_same_shape_leaves():
    params = layered_params(n_layers=12)
    opt = optim.make("gwt", lr=0.01, level=2)
    plan = opt.engine.plan(params)
    by_name = {b.name: b for b in plan.buckets}
    # 12 layers × (wq, wo same shape) -> one (24, d, d) bucket; w1/w2 pairs
    # bucket separately (different shapes); embed+norm run plain.
    st = opt.init(params)
    shapes = {name: jax.tree.leaves(s)[0].shape[0]
              for name, s in st["buckets"].items()}
    assert shapes["gwt_last__layer_0.attn.wo"] == 24
    assert shapes["gwt_last__layer_0.mlp.w1"] == 12
    assert shapes["gwt_last__layer_0.mlp.w2"] == 12
    assert sum(len(b.indices) for b in plan.buckets) == plan.n_leaves
    # bucket names are path-keyed and stable across re-planning
    assert set(by_name) == set(shapes)
    assert [b.name for b in opt.engine.plan(params).buckets] \
        == [b.name for b in plan.buckets]


def test_legacy_checkpoint_migrates_to_buckets(tmp_path):
    """Save under the pre-engine per-leaf tuple layout, restore + migrate
    into the bucketed layout, continue training identically."""
    from repro.checkpoint.manager import CheckpointManager, StructureMismatch
    params = layered_params()
    grads = jax.tree.map(lambda p: p * 0.01 + 0.001, params)
    for name, kw in [("gwt", {"level": 2}), ("adam", {}),
                     ("galore", {"rank": 4, "update_gap": 2})]:
        opt = optim.make(name, lr=0.01, **kw)
        p, st = run_steps(opt, params)
        legacy = opt.engine.to_legacy(st, params)  # old on-disk layout
        cm = CheckpointManager(str(tmp_path / name))
        cm.save(3, {"params": p, "opt": legacy}, blocking=True)
        # new-layout restore must fail loudly, not silently misreshape
        with pytest.raises(StructureMismatch):
            cm.restore(None, {"params": p, "opt": st})
        like = {"params": p, "opt": opt.engine.legacy_like(params)}
        saved, start = cm.restore(None, like)
        assert start == 3
        migrated = opt.engine.migrate_legacy(saved["opt"], params)
        for a, b in zip(jax.tree.leaves(migrated), jax.tree.leaves(st)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # one more step from the migrated state == from the original
        p1, _ = jax.jit(opt.update)(grads, migrated, saved["params"])
        p2, _ = jax.jit(opt.update)(grads, st, p)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_new_layout_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    params = layered_params()
    opt = optim.make("gwt", lr=0.01, level=2)
    p, st = run_steps(opt, params)
    cm = CheckpointManager(str(tmp_path))
    cm.save(7, {"params": p, "opt": st}, blocking=True)
    saved, start = cm.restore(None, {"params": p, "opt": st})
    assert start == 7
    for a, b in zip(jax.tree.leaves(saved["opt"]), jax.tree.leaves(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trace_size_sublinear_in_layers():
    """One scan body per bucket: jaxpr equations grow sublinearly in layer
    count, while the per-leaf loop grows ~linearly."""
    def eqns(n_layers, bucketed):
        opt = optim.make("gwt", lr=0.01, level=2, impl="jnp",
                         bucketed=bucketed)
        params = layered_params(n_layers)
        grads = jax.tree.map(lambda p: p * 0.01, params)
        st = opt.init(params)
        return len(jax.make_jaxpr(opt.update)(grads, st, params).eqns)

    b4, b16 = eqns(4, True), eqns(16, True)
    u4, u16 = eqns(4, False), eqns(16, False)
    assert b16 < u16 / 4, (b16, u16)           # bucketed is much smaller
    assert (b16 - b4) < (u16 - u4) / 4         # and grows much slower
    assert b16 / b4 < 16 / 4                   # sublinear in layer count


def test_state_bytes_exact_accounting():
    params = layered_params()
    n = sum(p.size for p in jax.tree.leaves(params))
    adam_bytes = engine.state_bytes(optim.make("adam", lr=1e-3), params)
    assert adam_bytes == 2 * n * 4 + 4  # m+v f32 per element (+step i32)
    # sgd keeps half of adam
    sgd_bytes = engine.state_bytes(optim.make("sgd", lr=1e-3), params)
    assert sgd_bytes == n * 4 + 4
    # gwt-2 compresses eligible leaves 4x
    gwt_bytes = engine.state_bytes(optim.make("gwt", lr=1e-3, level=2),
                                   params)
    assert gwt_bytes < adam_bytes / 2


def test_state_memory_bytes_adam_mini_host():
    """Adam-mini keeps a full M but only a per-row V — not 2× elements."""
    from repro.core.gwt import state_memory_bytes
    params = {"mlp": {"w": jnp.ones((16, 64))}}
    level = 2
    mem = state_memory_bytes(params, level, host="adam_mini")
    a_elems = 16 * (64 >> level)      # A_l band: (16, 16)
    assert mem["gwt_bytes"] == (a_elems + 16) * 2   # M + per-row V, bf16
    # ...and matches the engine's exact accounting structurally
    opt = optim.make("gwt", lr=1e-3, level=level, host="adam_mini")
    st = opt.init(params)
    host = st["buckets"]["gwt_last__mlp.w"]["host"]
    assert host["m"].shape == (1, 16, 16)
    assert host["v"].shape == (1, 16, 1)


def test_custom_rule_registration():
    """README example: a custom rule plugs into the engine unchanged."""
    sign_sgd = engine.LeafRule(
        kind="sign_sgd",
        init=lambda p: jnp.zeros((), jnp.float32),
        update=lambda g, p, s, step, leaf_id: (
            (p - 0.1 * jnp.sign(g)).astype(p.dtype), s + 1))
    opt = engine.build(lambda path, leaf: sign_sgd)
    params = layered_params(n_layers=2)
    grads = jax.tree.map(jnp.ones_like, params)
    st = opt.init(params)
    p2, st2 = jax.jit(opt.update)(grads, st, params)
    np.testing.assert_allclose(
        np.asarray(p2["layer_0"]["mlp"]["w1"]),
        np.asarray(params["layer_0"]["mlp"]["w1"]) - 0.1, rtol=1e-6)
    assert int(st2["step"]) == 1
    assert all(float(v[0]) == 1.0 for v in jax.tree.leaves(st2["buckets"]))


def test_default_eligible_has_no_block_param():
    """Eligibility is pure name/rank policy; divisibility by 2^level lives
    in _leaf_mode only, so the two can't disagree."""
    import inspect
    from repro.optim.base import default_eligible
    assert list(inspect.signature(default_eligible).parameters) \
        == ["path", "leaf"]
    assert default_eligible("layer/mlp/w", jnp.ones((6, 6)))
    assert not default_eligible("embed", jnp.ones((6, 6)))
    assert not default_eligible("layer/mlp/w", jnp.ones((6,)))


def test_bucketed_gwt_backend_sweep(kernel_impl):
    """Backend-sweep tier (conftest fixture): the bucketed GWT engine —
    including the fused vector_update path — matches the per-leaf jnp
    reference under every swept kernel impl."""
    params = layered_params()
    pb, sb = run_steps(optim.make("gwt", lr=0.01, level=2,
                                  impl=kernel_impl), params)
    pu, su = run_steps(optim.make("gwt", lr=0.01, level=2, bucketed=False,
                                  impl="jnp"), params)
    for a, b in zip(jax.tree.leaves(pb), jax.tree.leaves(pu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(sb), jax.tree.leaves(su)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("level", [1, 2, 4])
@pytest.mark.parametrize("orient", ["last", "first"])
def test_fused_write_level_orientation_sweep(level, orient):
    """Megakernel parity tier, optimizer level: the fused-write path
    (interpret) matches the staged per-leaf jnp engine across transform
    levels and both orientations.  FIRST-orient leaves ((32, 7): last
    axis indivisible) exercise the swap-in/swap-out of both g and p
    around the fused write; tolerance matches the existing GWT tier —
    the two paths schedule the Haar butterfly differently."""
    shape = (16, 64) if orient == "last" else (32, 7)
    k = jax.random.key(41)
    params = {"blk": {"mlp": {
        "w1": jax.random.normal(k, shape) * 0.1,
        "w2": jax.random.normal(jax.random.fold_in(k, 1), shape) * 0.1}}}
    pf, sf = run_steps(optim.make("gwt", lr=0.01, level=level,
                                  impl="interpret"), params)
    pj, sj = run_steps(optim.make("gwt", lr=0.01, level=level,
                                  impl="jnp"), params)
    bucket = f"gwt_{orient}__blk.mlp.w1"
    assert bucket in sf["buckets"], list(sf["buckets"])
    assert sf["buckets"][bucket]["host"]["m"].shape[0] == 2  # stacked pair
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pj)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(sf), jax.tree.leaves(sj)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_state_sharding_hint_structure_mismatch_raises():
    """A per-bucket placement hint whose structure drifted from the bucket
    state (wrong dict level, stale optimizer config) must fail loudly at
    init, not silently skip placement (the sharded train path depends on
    state being born on its mesh layout)."""
    params = {"mlp": {"w": jnp.zeros((8, 16))}}
    bad = {"gwt_last__mlp.w": {"host": 0}}      # missing m/v + prev_norm
    opt = optim.make("gwt", lr=1e-3, level=2, state_shardings=bad)
    with pytest.raises(ValueError, match="state_shardings hint"):
        opt.init(params)
    # hints for bucket names that don't exist are simply unused
    opt2 = optim.make("gwt", lr=1e-3, level=2,
                      state_shardings={"gwt_last__nope": {"host": 0}})
    st = opt2.init(params)
    assert "gwt_last__mlp.w" in st["buckets"]
