"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp ref oracle (assignment deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gwt_adam import kernel as kg, ops as gops, ref as rg
from repro.kernels.haar_dwt import kernel as kf, ref as rf

SHAPES_FWD = [(8, 128, 1), (32, 256, 2), (256, 512, 3), (16, 1024, 4),
              (128, 128, 2), (8, 256, 5), (40, 384, 1)]


@pytest.mark.parametrize("m,n,level", SHAPES_FWD)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_haar_dwt_fwd_inv_vs_ref(m, n, level, dtype):
    g = jax.random.normal(jax.random.key(1), (m, n), dtype)
    atol = 0.08 if dtype == jnp.bfloat16 else 1e-5
    outs_k = kf.haar_dwt_fwd(g, level, interpret=True)
    outs_r = rf.haar_dwt_fwd(g, level)
    assert outs_k[0].shape == (m, n >> level)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)
    rec = kf.haar_dwt_inv(outs_k[0], outs_k[1:], interpret=True)
    np.testing.assert_allclose(np.asarray(rec, np.float32),
                               np.asarray(g, np.float32), atol=atol)


@pytest.mark.parametrize("m,n,level", [(8, 128, 1), (64, 512, 2),
                                       (256, 2048, 3), (32, 256, 4)])
def test_gwt_adam_fused_vs_ref(m, n, level):
    k = jax.random.key(2)
    g = jax.random.normal(k, (m, n), jnp.float32)
    ms = jnp.abs(jax.random.normal(jax.random.fold_in(k, 1),
                                   (m, n >> level))) * 0.1
    vs = jnp.abs(jax.random.normal(jax.random.fold_in(k, 2),
                                   (m, n >> level))) * 0.01
    outs_k = kg.gwt_adam_tile(g, ms, vs, level=level, interpret=True)
    outs_r = rg.gwt_adam_tile(g, ms, vs, level=level)
    for i, (a, b) in enumerate(zip(outs_k[:3], outs_r[:3])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4, err_msg=f"out{i}")
    np.testing.assert_allclose(float(outs_k[3].sum()),
                               float(outs_r[3].sum()), rtol=1e-4)


def test_gwt_adam_bf16_grad_f32_state():
    g = jax.random.normal(jax.random.key(3), (64, 256), jnp.bfloat16)
    ms = jnp.zeros((64, 64), jnp.float32)
    vs = jnp.zeros((64, 64), jnp.float32)
    outs_k = kg.gwt_adam_tile(g, ms, vs, level=2, interpret=True)
    outs_r = rg.gwt_adam_tile(g, ms, vs, level=2)
    np.testing.assert_allclose(np.asarray(outs_k[0], np.float32),
                               np.asarray(outs_r[0], np.float32), atol=0.15)
    np.testing.assert_allclose(outs_k[2], outs_r[2], rtol=1e-2, atol=1e-5)


def test_fused_update_stacked_leaves():
    """(L, m, n) scan-stacked leaves route through vmap."""
    g = jax.random.normal(jax.random.key(4), (3, 64, 256))
    st = {"m": jnp.zeros((3, 64, 64)), "v": jnp.zeros((3, 64, 64))}
    gt1, lm1, st1 = gops.fused_update(g, st, jnp.int32(0), level=2,
                                      impl="interpret")
    gt2, lm2, st2 = gops.fused_update(g, st, jnp.int32(0), level=2,
                                      impl="jnp")
    np.testing.assert_allclose(gt1, gt2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st1["v"], st2["v"], rtol=1e-5, atol=1e-7)
    assert float(lm1) == pytest.approx(float(lm2))


def test_block_picker_constraints():
    for (m, n, level) in [(8, 128, 1), (1024, 4096, 3), (333, 768, 2)]:
        bm, bn = kg._pick_blocks(m, n, level)
        assert m % bm == 0 and n % bn == 0
        assert bn % (1 << level) == 0
        assert 4 * bm * bn * 4 <= 8 * 1024 * 1024  # fits VMEM budget


def test_fused_update_backend_sweep(kernel_impl):
    """Backend-sweep tier (conftest fixture): the optimizer-facing
    fused_update entry point agrees with the pure-jnp ref oracle under
    every swept impl (jnp fast tier, interpret via --runslow; pallas
    rides the same knob on TPU)."""
    m, n, level = 64, 256, 2
    k = jax.random.key(11)
    g = jax.random.normal(k, (m, n), jnp.float32)
    st = {"m": jnp.abs(jax.random.normal(jax.random.fold_in(k, 1),
                                         (m, n >> level))) * 0.1,
          "v": jnp.abs(jax.random.normal(jax.random.fold_in(k, 2),
                                         (m, n >> level))) * 0.01}
    gt_k, lm_k, st_k = gops.fused_update(g, st, jnp.int32(3), level=level,
                                         impl=kernel_impl)
    gt_r, mr, vr, _ = rg.gwt_adam_tile(g, st["m"], st["v"], level=level)
    np.testing.assert_allclose(np.asarray(gt_k), np.asarray(gt_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k["m"]), np.asarray(mr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_k["v"]), np.asarray(vr),
                               rtol=1e-5, atol=1e-7)
