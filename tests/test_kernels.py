"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp ref oracle (assignment deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gwt_adam import kernel as kg, ops as gops, ref as rg
from repro.kernels.haar_dwt import kernel as kf, ref as rf

SHAPES_FWD = [(8, 128, 1), (32, 256, 2), (256, 512, 3), (16, 1024, 4),
              (128, 128, 2), (8, 256, 5), (40, 384, 1)]


@pytest.mark.parametrize("m,n,level", SHAPES_FWD)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_haar_dwt_fwd_inv_vs_ref(m, n, level, dtype):
    g = jax.random.normal(jax.random.key(1), (m, n), dtype)
    atol = 0.08 if dtype == jnp.bfloat16 else 1e-5
    outs_k = kf.haar_dwt_fwd(g, level, interpret=True)
    outs_r = rf.haar_dwt_fwd(g, level)
    assert outs_k[0].shape == (m, n >> level)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)
    rec = kf.haar_dwt_inv(outs_k[0], outs_k[1:], interpret=True)
    np.testing.assert_allclose(np.asarray(rec, np.float32),
                               np.asarray(g, np.float32), atol=atol)


@pytest.mark.parametrize("m,n,level", [(8, 128, 1), (64, 512, 2),
                                       (256, 2048, 3), (32, 256, 4)])
def test_gwt_adam_fused_vs_ref(m, n, level):
    k = jax.random.key(2)
    g = jax.random.normal(k, (m, n), jnp.float32)
    ms = jnp.abs(jax.random.normal(jax.random.fold_in(k, 1),
                                   (m, n >> level))) * 0.1
    vs = jnp.abs(jax.random.normal(jax.random.fold_in(k, 2),
                                   (m, n >> level))) * 0.01
    outs_k = kg.gwt_adam_tile(g, ms, vs, level=level, interpret=True)
    outs_r = rg.gwt_adam_tile(g, ms, vs, level=level)
    for i, (a, b) in enumerate(zip(outs_k[:3], outs_r[:3])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4, err_msg=f"out{i}")
    np.testing.assert_allclose(float(outs_k[3].sum()),
                               float(outs_r[3].sum()), rtol=1e-4)


def test_gwt_adam_bf16_grad_f32_state():
    g = jax.random.normal(jax.random.key(3), (64, 256), jnp.bfloat16)
    ms = jnp.zeros((64, 64), jnp.float32)
    vs = jnp.zeros((64, 64), jnp.float32)
    outs_k = kg.gwt_adam_tile(g, ms, vs, level=2, interpret=True)
    outs_r = rg.gwt_adam_tile(g, ms, vs, level=2)
    np.testing.assert_allclose(np.asarray(outs_k[0], np.float32),
                               np.asarray(outs_r[0], np.float32), atol=0.15)
    np.testing.assert_allclose(outs_k[2], outs_r[2], rtol=1e-2, atol=1e-5)


def test_fused_update_stacked_leaves():
    """(L, m, n) scan-stacked leaves route through vmap."""
    g = jax.random.normal(jax.random.key(4), (3, 64, 256))
    st = {"m": jnp.zeros((3, 64, 64)), "v": jnp.zeros((3, 64, 64))}
    gt1, lm1, st1 = gops.fused_update(g, st, jnp.int32(0), level=2,
                                      impl="interpret")
    gt2, lm2, st2 = gops.fused_update(g, st, jnp.int32(0), level=2,
                                      impl="jnp")
    np.testing.assert_allclose(gt1, gt2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st1["v"], st2["v"], rtol=1e-5, atol=1e-7)
    assert float(lm1) == pytest.approx(float(lm2))


# ---------------------------------------------------------------------------
# Fused-write (megakernel) parity tier: one launch per bucket performs
# DWT→Adam→inverse→limit→param-write.  impl='jnp' routes to the tiled ref
# oracle whose norm reduction replicates the kernel's row-block
# association, so the whole staged core — moments, requantized q8 state,
# and the two-pass limiter norms — is BITWISE identical under interpret.
# Only the terminal write chain ``p - step·g̃`` may diverge: the
# interpret and jnp lowerings make independent FMA-contraction choices
# there, so new_p is pinned to a contraction error bound — elementwise
# |Δ| ≤ a few spacings of the operand magnitude — instead of equality.
# ---------------------------------------------------------------------------

FUSED_WRITE_SHAPES = [(1, 16, 128, 1), (3, 24, 64, 2), (2, 32, 512, 4)]


def _assert_write_parity(a, b, p_in, slack=4):
    """new_p from two lowerings of the same write chain
    (``p - step·(g̃·coef) [- wd·p]``): each multiply/subtract is an FMA
    candidate the two backends contract independently, so the elementwise
    difference is a handful of rounding errors at the magnitude of the
    chain's operands (measured worst: 2.5 spacings at level 4; asserted
    ≤ ``slack`` spacings of the largest of |a|,|b|,|p_in|)."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    mag = np.maximum(np.maximum(np.abs(a), np.abs(b)),
                     np.abs(np.asarray(p_in, np.float32)))
    tol = slack * np.spacing(mag.astype(np.float32))
    diff = np.abs(a - b)
    bad = diff > tol
    assert not bad.any(), (int(bad.sum()), float(diff[bad].max()))


def _fused_write_inputs(L, m, n, level, dtype=jnp.float32):
    k = jax.random.key(6)
    g = jax.random.normal(k, (L, m, n), dtype)
    p = jax.random.normal(jax.random.fold_in(k, 1), (L, m, n), dtype)
    st = {"m": jnp.abs(jax.random.normal(jax.random.fold_in(k, 2),
                                         (L, m, n >> level))) * 0.1,
          "v": jnp.abs(jax.random.normal(jax.random.fold_in(k, 3),
                                         (L, m, n >> level))) * 0.01}
    # leaf 0 enters with prev_norm == 0 (first-step limiter case)
    pn = jnp.arange(L, dtype=jnp.float32) * 0.3
    return g, p, st, pn


def _fused_write_kw(level, **over):
    kw = dict(lr_t=jnp.float32(0.01), alpha=0.25, weight_decay=0.0,
              gamma=1.01, use_limiter=True, level=level)
    kw.update(over)
    return kw


@pytest.mark.parametrize("L,m,n,level", FUSED_WRITE_SHAPES)
@pytest.mark.parametrize("use_limiter", [True, False])
def test_fused_write_core_bitwise_vs_staged_oracle(L, m, n, level,
                                                   use_limiter):
    g, p, st, pn = _fused_write_inputs(L, m, n, level)
    kw = _fused_write_kw(level, use_limiter=use_limiter)
    pi, ni, si = gops.fused_write_update(g, p, st, jnp.int32(2), pn,
                                         impl="interpret", **kw)
    pj, nj, sj = gops.fused_write_update(g, p, st, jnp.int32(2), pn,
                                         impl="jnp", **kw)
    for tag, a, b in [("norm", ni, nj),
                      ("m", si["m"], sj["m"]), ("v", si["v"], sj["v"])]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=tag)
    _assert_write_parity(pi, pj, p)


def test_fused_write_bf16_params_vs_staged_oracle():
    """bf16 grads/params (f32 moments): the fused write rounds new_p to
    bf16 exactly once, same as the staged oracle — ≤1 bf16 ulp, bitwise
    in practice for weight_decay == 0."""
    g, p, st, pn = _fused_write_inputs(2, 16, 256, 2, dtype=jnp.bfloat16)
    kw = _fused_write_kw(2)
    pi, ni, si = gops.fused_write_update(g, p, st, jnp.int32(1), pn,
                                         impl="interpret", **kw)
    pj, nj, sj = gops.fused_write_update(g, p, st, jnp.int32(1), pn,
                                         impl="jnp", **kw)
    assert pi.dtype == jnp.bfloat16
    bits_i = np.asarray(pi).view(np.uint16).astype(np.int32)
    bits_j = np.asarray(pj).view(np.uint16).astype(np.int32)
    assert np.abs(bits_i - bits_j).max() <= 1
    np.testing.assert_array_equal(np.asarray(ni), np.asarray(nj))
    np.testing.assert_array_equal(np.asarray(si["m"]), np.asarray(sj["m"]))
    np.testing.assert_array_equal(np.asarray(si["v"]), np.asarray(sj["v"]))


def test_fused_write_weight_decay_within_fma_bound():
    """weight_decay != 0 adds one more FMA opportunity to the write chain
    (the decoupled ``- wd_coef·p`` term): new_p stays within the same
    contraction bound; everything upstream of the write stays bitwise."""
    g, p, st, pn = _fused_write_inputs(2, 32, 512, 4)
    kw = _fused_write_kw(4, weight_decay=0.01)
    pi, ni, si = gops.fused_write_update(g, p, st, jnp.int32(2), pn,
                                         impl="interpret", **kw)
    pj, nj, sj = gops.fused_write_update(g, p, st, jnp.int32(2), pn,
                                         impl="jnp", **kw)
    _assert_write_parity(pi, pj, p)
    np.testing.assert_array_equal(np.asarray(ni), np.asarray(nj))
    np.testing.assert_array_equal(np.asarray(si["m"]), np.asarray(sj["m"]))
    np.testing.assert_array_equal(np.asarray(si["v"]), np.asarray(sj["v"]))


def _q8_encoded_state(L, m, na, block=64, seed=9):
    from repro.optim import codec
    k = jax.random.key(seed)
    key = codec.make_key(0)
    leaf_ids = jnp.arange(L, dtype=jnp.uint32)
    step0 = jnp.uint32(0)
    mf = jnp.abs(jax.random.normal(jax.random.fold_in(k, 4),
                                   (L, m, na))) * 0.1
    vf = jnp.abs(jax.random.normal(jax.random.fold_in(k, 5),
                                   (L, m, na))) * 0.01
    enc = {"m": {"q": [], "scale": []}, "v": {"q": [], "scale": []}}
    for slot, src in ((0, mf), (1, vf)):
        name = "m" if slot == 0 else "v"
        for l in range(L):
            salt = codec.slot_salt(key, step0, slot, leaf_ids[l])
            q, s = codec.blocked_quant(src[l], salt, block)
            enc[name]["q"].append(q)
            enc[name]["scale"].append(s)
    st = {n: {"q": jnp.stack(enc[n]["q"]),
              "scale": jnp.stack(enc[n]["scale"])} for n in ("m", "v")}
    return st, key, leaf_ids


def test_fused_write_q8_bitwise_vs_staged_oracle():
    """int8-codec megakernel: dequant→update→requant AND limit+write in
    one launch.  The requantize is a pure function of (salt, flat index),
    so the int8 payloads and scales are bitwise vs the tiled oracle; the
    param write carries the usual single-FMA contraction bound."""
    L, m, n, level = 2, 16, 256, 2
    g, p, _, pn = _fused_write_inputs(L, m, n, level)
    st, key, leaf_ids = _q8_encoded_state(L, m, n >> level)
    kw = _fused_write_kw(level)
    pi, ni, si = gops.fused_write_update_q8(
        g, p, st, jnp.int32(1), key, leaf_ids, pn, impl="interpret", **kw)
    pj, nj, sj = gops.fused_write_update_q8(
        g, p, st, jnp.int32(1), key, leaf_ids, pn, impl="jnp", **kw)
    _assert_write_parity(pi, pj, p)
    np.testing.assert_array_equal(np.asarray(ni), np.asarray(nj))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), si, sj)


def test_fused_write_q8_nontileable_falls_back_to_oracle():
    """Shapes the q8 kernel cannot tile block-aligned (m·n_A not a
    multiple of the codec block) fall back to the jnp oracle under any
    impl — a static per-bucket decision, bitwise across backends."""
    L, m, n, level = 1, 12, 8, 1
    assert kg.q8_row_block(m, n, level, 64) is None
    g, p, _, pn = _fused_write_inputs(L, m, n, level)
    st, key, leaf_ids = _q8_encoded_state(L, m, n >> level)
    kw = _fused_write_kw(level)
    pi, ni, si = gops.fused_write_update_q8(
        g, p, st, jnp.int32(1), key, leaf_ids, pn, impl="interpret", **kw)
    pj, nj, sj = gops.fused_write_update_q8(
        g, p, st, jnp.int32(1), key, leaf_ids, pn, impl="jnp", **kw)
    assert np.isfinite(np.asarray(pi)).all()
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(pj))
    np.testing.assert_array_equal(np.asarray(ni), np.asarray(nj))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), si, sj)


# ---------------------------------------------------------------------------
# Hardware (non-interpret) parity leg: interpret mode preserves unwritten
# output windows on revisit, which masks copy-out hazards in the two-phase
# limiter pass (a phase-0 grid step that skips its aliased p/m/v output
# blocks clobbers the state phase 1 re-reads on real TPUs).  These tests
# re-run the fused-write contract with impl='pallas' on hardware, with
# gm > 1 row tiles and the limiter on — the configuration that hazard
# corrupts.  Skipped off-TPU (the REPRO_KERNEL_IMPL backlog tier).
# ---------------------------------------------------------------------------

needs_tpu = pytest.mark.skipif(jax.default_backend() != "tpu",
                               reason="hardware Pallas parity needs a TPU")


@needs_tpu
@pytest.mark.parametrize("use_limiter", [True, False])
def test_fused_write_hardware_pallas_vs_staged_oracle(use_limiter):
    L, m, n, level = 2, 256, 2048, 2
    assert m // kg.fused_row_block(m, n, level) > 1  # multi-tile leaves
    g, p, st, pn = _fused_write_inputs(L, m, n, level)
    kw = _fused_write_kw(level, use_limiter=use_limiter)
    pi, ni, si = gops.fused_write_update(g, p, st, jnp.int32(2), pn,
                                         impl="pallas", **kw)
    pj, nj, sj = gops.fused_write_update(g, p, st, jnp.int32(2), pn,
                                         impl="jnp", **kw)
    # Mosaic and XLA:TPU may contract FMAs differently, so hardware pins
    # allclose rather than the interpret tier's bitwise equality — still
    # far tighter than the garbage an output-window clobber produces.
    np.testing.assert_allclose(np.asarray(ni), np.asarray(nj),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(si["m"]), np.asarray(sj["m"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(si["v"]), np.asarray(sj["v"]),
                               rtol=1e-5, atol=1e-7)
    _assert_write_parity(pi, pj, p, slack=8)


@needs_tpu
def test_fused_write_q8_hardware_pallas_vs_staged_oracle():
    L, m, n, level = 2, 256, 2048, 2
    assert m // kg.q8_row_block(m, n, level, 64) > 1
    g, p, _, pn = _fused_write_inputs(L, m, n, level)
    st, key, leaf_ids = _q8_encoded_state(L, m, n >> level)
    kw = _fused_write_kw(level)
    pi, ni, si = gops.fused_write_update_q8(
        g, p, st, jnp.int32(1), key, leaf_ids, pn, impl="pallas", **kw)
    pj, nj, sj = gops.fused_write_update_q8(
        g, p, st, jnp.int32(1), key, leaf_ids, pn, impl="jnp", **kw)
    np.testing.assert_allclose(np.asarray(ni), np.asarray(nj),
                               rtol=1e-5, atol=1e-6)
    # an ulp of pre-quant drift can flip a stochastic-rounding bit, so
    # int8 payloads get a ±1-code budget; scales stay allclose
    for tag in ("m", "v"):
        qi = np.asarray(si[tag]["q"], np.int32)
        qj = np.asarray(sj[tag]["q"], np.int32)
        assert np.abs(qi - qj).max() <= 1, tag
        np.testing.assert_allclose(np.asarray(si[tag]["scale"]),
                                   np.asarray(sj[tag]["scale"]),
                                   rtol=1e-6, atol=0, err_msg=tag)
    _assert_write_parity(pi, pj, p, slack=8)


def test_wire_dwt_quantize_pack_bitwise_vs_jnp():
    """The wire-path sibling fusion: haar_dwt_fwd_q emits (A f32,
    D bf16/f8) in one launch, bitwise vs the jnp reduce_terms split."""
    from repro.kernels.haar_dwt import ops as dops
    g = jax.random.normal(jax.random.key(12), (24, 256), jnp.float32)
    for dt in (jnp.bfloat16, jnp.float8_e4m3fn):
        bk = dops.dwt_wire(g, 2, dt, impl="interpret")
        br = dops.dwt_wire(g, 2, dt, impl="jnp")
        assert bk[0].dtype == jnp.float32
        assert all(d.dtype == dt for d in bk[1:])
        for a, b in zip(bk, br):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_block_picker_constraints():
    for (m, n, level) in [(8, 128, 1), (1024, 4096, 3), (333, 768, 2)]:
        bm, bn = kg._pick_blocks(m, n, level)
        assert m % bm == 0 and n % bn == 0
        assert bn % (1 << level) == 0
        assert 4 * bm * bn * 4 <= 8 * 1024 * 1024  # fits VMEM budget


def test_fused_update_backend_sweep(kernel_impl):
    """Backend-sweep tier (conftest fixture): the optimizer-facing
    fused_update entry point agrees with the pure-jnp ref oracle under
    every swept impl (jnp fast tier, interpret via --runslow; pallas
    rides the same knob on TPU)."""
    m, n, level = 64, 256, 2
    k = jax.random.key(11)
    g = jax.random.normal(k, (m, n), jnp.float32)
    st = {"m": jnp.abs(jax.random.normal(jax.random.fold_in(k, 1),
                                         (m, n >> level))) * 0.1,
          "v": jnp.abs(jax.random.normal(jax.random.fold_in(k, 2),
                                         (m, n >> level))) * 0.01}
    gt_k, lm_k, st_k = gops.fused_update(g, st, jnp.int32(3), level=level,
                                         impl=kernel_impl)
    gt_r, mr, vr, _ = rg.gwt_adam_tile(g, st["m"], st["v"], level=level)
    np.testing.assert_allclose(np.asarray(gt_k), np.asarray(gt_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k["m"]), np.asarray(mr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_k["v"]), np.asarray(vr),
                               rtol=1e-5, atol=1e-7)
