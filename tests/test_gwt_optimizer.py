"""GWT optimizer (Algorithm 1) behaviour tests + baseline optimizers."""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import limiter

gwt_mod = importlib.import_module("repro.core.gwt")


def make_params(key=0):
    k = jax.random.key(key)
    return {"mlp": {"w1": jax.random.normal(k, (16, 32)) * 0.1,
                    "w2": jax.random.normal(jax.random.fold_in(k, 1),
                                            (32, 16)) * 0.1},
            "embed": jax.random.normal(jax.random.fold_in(k, 2), (10, 16)),
            "norm": jnp.ones((16,))}


def test_level0_equals_host_adam():
    params = make_params()
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    o0 = optim.make("gwt", lr=0.01, level=0, alpha=1.0, use_limiter=False)
    oa = optim.make("adam", lr=0.01)
    s0, sa = o0.init(params), oa.init(params)
    p0, p1 = params, params
    for _ in range(3):
        p0, s0 = jax.jit(o0.update)(grads, s0, p0)
        p1, sa = jax.jit(oa.update)(grads, sa, p1)
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_state_memory_matches_table1():
    """Table I: GWT optimizer states = mn/2^{l-1} elements on GWT leaves."""
    params = make_params()
    for level in (1, 2, 3):
        mem = gwt_mod.state_memory_bytes(params, level)
        gwt_elems = (16 * 32 + 32 * 16)  # the two eligible mlp mats
        # Table I: states = mn/2^{l-1} elements (M^R+V^R) -> x2 bytes (bf16)
        assert mem["gwt_bytes"] == gwt_elems // (1 << (level - 1)) * 2
        # embed (10x16) + norm (16) run plain Adam: 2 states full size
        assert mem["plain_bytes"] == 2 * (10 * 16 + 16) * 2


def test_module_wise_policy():
    """Embeddings/norms stay uncompressed (paper's module-wise strategy)."""
    params = make_params()
    o = optim.make("gwt", lr=0.01, level=2)
    st = o.init(params)
    plan = o.engine.plan(params)
    for b in plan.buckets:
        bstate = st["buckets"][b.name]
        if any("mlp" in p for p in b.paths):
            assert b.rule.kind in ("gwt_last", "gwt_first"), b.name
            assert "prev_norm" in bstate, b.name
            for path in b.paths:
                w = params["mlp"][path.split("/")[1]]
                m = bstate["host"]["m"]
                assert (m.shape[-1] * 4 == w.shape[-1]
                        or m.shape[-2] * 4 == w.shape[-2]), b.name
        else:
            assert b.rule.kind == "plain", b.name
            assert "prev_norm" not in bstate, b.name


def test_transform_axis_fallback():
    """Last axis not divisible -> transform along first axis."""
    params = {"mlp": {"w": jnp.ones((32, 6))}}  # 6 % 4 != 0, 32 % 4 == 0
    o = optim.make("gwt", lr=0.01, level=2)
    st = o.init(params)
    m = st["buckets"]["gwt_first__mlp.w"]["host"]["m"]
    assert m.shape == (1, 6, 8)  # stacked, swapped, halved twice
    g = {"mlp": {"w": jnp.ones((32, 6)) * 0.1}}
    p2, _ = jax.jit(o.update)(g, st, params)
    assert p2["mlp"]["w"].shape == (32, 6)
    assert not np.any(np.isnan(np.asarray(p2["mlp"]["w"], np.float32)))


def test_norm_growth_limiter():
    u1 = jnp.ones((4, 4))
    lim1, n1 = limiter.limit(u1, jnp.zeros(()))   # first step: no limiting
    np.testing.assert_allclose(lim1, u1)
    big = jnp.ones((4, 4)) * 100.0
    lim2, n2 = limiter.limit(big, n1, gamma=1.01)
    np.testing.assert_allclose(float(jnp.linalg.norm(lim2)),
                               1.01 * float(n1), rtol=1e-5)
    small = jnp.ones((4, 4)) * 0.001
    lim3, _ = limiter.limit(small, n2)            # shrinking: untouched
    np.testing.assert_allclose(lim3, small)


def test_norm_growth_limiter_zero_update_keeps_prev():
    """An all-zero update (frozen leaf, masked step) must NOT reset the
    norm history: prev_norm carries through, so the next real update is
    still limited against the established trajectory instead of sailing
    through an accidentally-cleared limiter."""
    u1 = jnp.ones((4, 4))
    _, n1 = limiter.limit(u1, jnp.zeros(()))
    assert float(n1) > 0
    lim0, n0 = limiter.limit(jnp.zeros((4, 4)), n1, gamma=1.01)
    np.testing.assert_allclose(np.asarray(lim0), 0.0)
    assert float(n0) == float(n1)       # history preserved, not zeroed
    big = jnp.ones((4, 4)) * 100.0
    lim2, _ = limiter.limit(big, n0, gamma=1.01)
    np.testing.assert_allclose(float(jnp.linalg.norm(lim2)),
                               1.01 * float(n1), rtol=1e-5)


def test_gwt_spike_suppression():
    """NL keeps the update norm trajectory within gamma^t growth."""
    params = {"m": {"w": jnp.zeros((8, 16))}}
    o = optim.make("gwt", lr=0.1, level=2, gamma=1.01)
    st = o.init(params)
    prev_norm = None
    p = params
    for i in range(5):
        scale = 100.0 if i == 3 else 0.01   # gradient spike at step 3
        g = {"m": {"w": jnp.full((8, 16), scale)}}
        p_new, st = jax.jit(o.update)(g, st, p)
        delta = np.linalg.norm(np.asarray(p_new["m"]["w"] - p["m"]["w"],
                                          np.float32))
        if prev_norm is not None and prev_norm > 0:
            assert delta <= prev_norm * 1.01 * 1.05 + 1e-9, (i, delta)
        prev_norm = delta
        p = p_new


@pytest.mark.parametrize("name,kw", [
    ("adam", {}), ("adam_mini", {}), ("muon", {}), ("sgd", {}),
    ("galore", {"rank": 4, "update_gap": 5}),
    ("apollo", {"rank": 4, "update_gap": 5}),
    ("fira", {"rank": 4, "update_gap": 5}),
    ("gwt", {"level": 1}), ("gwt", {"level": 3}),
    ("gwt", {"level": 2, "host": "adam_mini"}),
    ("gwt", {"level": 2, "host": "muon"}),
])
def test_optimizers_converge_on_quadratic(name, kw):
    def loss_fn(params):
        return sum(jnp.sum((l - 0.5) ** 2) for l in jax.tree.leaves(params))

    from repro.optim.schedules import warmup_cosine
    # normalized-update optimizers need lr decay to settle on a quadratic
    o = optim.make(name, lr=warmup_cosine(0.05, 60, warmup_frac=0.05,
                                          final_frac=0.02), **kw)
    ps = {"mlp": {"w1": jax.random.normal(jax.random.key(0), (16, 32))}}
    st = o.init(ps)
    l0 = float(loss_fn(ps))
    upd = jax.jit(o.update)
    for _ in range(60):
        ps, st = upd(jax.grad(loss_fn)(ps), st, ps)
    assert float(loss_fn(ps)) < 0.9 * l0


def test_gwt_equals_fused_kernel_path():
    """jnp core path == fused-kernel (interpret) path, leaf by leaf."""
    from repro.kernels.gwt_adam import ops as gops
    g = jax.random.normal(jax.random.key(3), (64, 256))
    st = {"m": jnp.zeros((64, 64)), "v": jnp.zeros((64, 64))}
    for step in range(3):
        gt_i, lm_i, st_i = gops.fused_update(g, st, jnp.int32(step),
                                             level=2, impl="interpret")
        gt_j, lm_j, st_j = gops.fused_update(g, st, jnp.int32(step),
                                             level=2, impl="jnp")
        np.testing.assert_allclose(gt_i, gt_j, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(st_i["v"], st_j["v"], rtol=1e-5, atol=1e-6)
        st = st_i
        g = g * 0.9


def test_galore_projector_refresh():
    """Projection refreshes every update_gap steps (SVD under lax.cond)."""
    o = optim.make("galore", lr=0.01, rank=2, update_gap=3)
    params = {"mlp": {"w": jax.random.normal(jax.random.key(0), (8, 16))}}
    st = o.init(params)
    proj = lambda st: np.asarray(st["buckets"]["galore__mlp.w"]["proj"])
    g1 = {"mlp": {"w": jax.random.normal(jax.random.key(1), (8, 16))}}
    params, st = jax.jit(o.update)(g1, st, params)     # step0: refresh
    p_after_0 = proj(st)
    g2 = {"mlp": {"w": jax.random.normal(jax.random.key(2), (8, 16))}}
    params, st = jax.jit(o.update)(g2, st, params)     # step1: keep
    np.testing.assert_allclose(proj(st), p_after_0)
    params, st = jax.jit(o.update)(g2, st, params)     # step2: keep
    params, st = jax.jit(o.update)(g2, st, params)     # step3: refresh
    assert not np.allclose(proj(st), p_after_0)


def test_gwt_update_orthonormal_energy_invariant():
    """The pre-limiter GWT update in the wavelet domain has the same energy
    as in the original domain (H orthonormal) — property of Algorithm 1's
    reconstruction step."""
    from repro.core import haar
    from repro.optim import hosts
    g = jax.random.normal(jax.random.key(5), (32, 128))
    host = hosts.adam()
    a, ds = haar.haar_forward(g, 2)
    st = host.init(jax.ShapeDtypeStruct(a.shape, jnp.float32))
    pre, dsc, _, _ = host.update(a, st, jnp.int32(0))
    tilde = [d * haar.detail_scale_upsample(dsc, 2, 2 - i)
             for i, d in enumerate(ds)]
    gt = haar.haar_inverse(pre, tilde)
    e_wave = float(jnp.sum(pre**2) + sum(jnp.sum(t**2) for t in tilde))
    e_orig = float(jnp.sum(gt**2))
    np.testing.assert_allclose(e_wave, e_orig, rtol=1e-5)


def test_gwt_wavelet_choice_changes_subspace_not_memory():
    """haar vs db2: identical state shapes/memory, different subspace."""
    params = {"mlp": {"w": jax.random.normal(jax.random.key(1), (16, 64))}}
    g = {"mlp": {"w": jax.random.normal(jax.random.key(2), (16, 64)) * 0.1}}
    outs = {}
    for wavelet in ("haar", "db2"):
        o = optim.make("gwt", lr=0.01, level=2, wavelet=wavelet,
                       use_limiter=False)
        st = o.init(params)
        m = st["buckets"]["gwt_last__mlp.w"]["host"]["m"]
        assert m.shape == (1, 16, 16), wavelet
        p2, _ = jax.jit(o.update)(g, st, params)
        outs[wavelet] = np.asarray(p2["mlp"]["w"], np.float32)
    assert not np.allclose(outs["haar"], outs["db2"], atol=1e-6)


def test_gwt_handles_zero_gradients():
    params = {"mlp": {"w": jnp.ones((8, 16))}}
    g = {"mlp": {"w": jnp.zeros((8, 16))}}
    o = optim.make("gwt", lr=0.01, level=2)
    st = o.init(params)
    p2, st = jax.jit(o.update)(g, st, params)
    assert np.all(np.isfinite(np.asarray(p2["mlp"]["w"], np.float32)))
