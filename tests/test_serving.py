"""Serving runtime: slot-paged KV cache, chunked prefill, the
continuous-batching engine, int8 KV quantization, and the
train → checkpoint → serve round trip.

Greedy-equality assertions are stable here: CPU XLA is deterministic, so
a paged schedule that computes the same attention as the dense path
yields bit-identical logits and therefore identical argmax tokens.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.checkpoint.manager import CheckpointManager, StructureMismatch
from repro.data.pipeline import SyntheticLM
from repro.launch.serve import ensure_capacity, generate, pad_cache
from repro.models import lm
from repro.serve import kv as kv_lib
from repro.serve.engine import Engine, EngineConfig, Request


def _smoke():
    return configs.get_smoke("llama-60m")


def _params(cfg, seed=0):
    return lm.init(cfg, jax.random.PRNGKey(seed))


def _requests(cfg, n, seed=3, max_prompt=20, max_gen=8):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab,
                                       int(rng.randint(3, max_prompt))).tolist(),
                    max_gen=int(rng.randint(1, max_gen + 1)))
            for i in range(n)]


# ---------------------------------------------------------------------------
# Paged substrate vs dense decode
# ---------------------------------------------------------------------------

def test_paged_decode_matches_dense():
    """Hand-driven paged chunk-prefill + decode reproduces the dense
    prefill/decode greedy tokens exactly (prompt crosses page boundaries,
    final chunk is padded)."""
    cfg = _smoke()
    params = _params(cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 7), 0, cfg.vocab)
    GEN, PAGE, MP = 5, 4, 4
    ref = generate(cfg, params, prompt, GEN)[0].tolist()

    pools = lm.init_paged_caches(cfg, 1 + 2 * MP, PAGE)
    page_table = jnp.zeros((2, MP), jnp.int32).at[0, :3].set(
        jnp.array([1, 2, 3]))
    chunk_step = lm.make_chunk_prefill_step(cfg)
    decode_step = lm.make_paged_decode_step(cfg)

    filled, last_logits = 0, None
    for start in range(0, 7, PAGE):
        chunk = prompt[:, start:start + PAGE]
        last_logits, pools = chunk_step(params, pools, page_table[:1],
                                        jnp.array([filled], jnp.int32), chunk)
        filled += chunk.shape[1]
    nxt = jnp.argmax(last_logits[0, -1]).astype(jnp.int32)
    out = [int(nxt)]
    lens = jnp.array([7, 0], jnp.int32)
    for _ in range(GEN - 1):
        tokens = jnp.zeros((2, 1), jnp.int32).at[0, 0].set(nxt)
        logits, pools = decode_step(params, pools, page_table, lens, tokens)
        lens = lens.at[0].add(1)
        nxt = jnp.argmax(logits[0]).astype(jnp.int32)
        out.append(int(nxt))
    assert out == ref


def test_chunked_prefill_matches_single_shot_logits():
    """Last-prompt-position logits from chunked paged prefill ≈ the
    single-shot dense prefill (same math, different summation order)."""
    cfg = _smoke()
    params = _params(cfg, seed=2)
    PLEN, CHUNK, PAGE = 40, 16, 8
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, PLEN), 0,
                                cfg.vocab)
    ref_logits, _ = jax.jit(lm.make_prefill_step(cfg))(
        params, {"tokens": prompt})

    MP = -(-(PLEN + 1) // PAGE)
    pools = lm.init_paged_caches(cfg, 1 + MP, PAGE)
    pt = jnp.arange(1, MP + 1, dtype=jnp.int32)[None, :]
    chunk_step = lm.make_chunk_prefill_step(cfg)
    filled, logits = 0, None
    while filled < PLEN:
        chunk = prompt[:, filled:filled + CHUNK]
        pad = CHUNK - chunk.shape[1]
        if pad:      # fixed chunk shape: padded tail past the prompt end
            chunk = jnp.pad(chunk, ((0, 0), (0, pad)))
        logits, pools = chunk_step(params, pools, pt,
                                   jnp.array([filled], jnp.int32), chunk)
        filled += CHUNK - pad
    last = logits[0, (PLEN - 1) % CHUNK]
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref_logits[0]),
                               atol=1e-3, rtol=1e-4)


def test_int8_kv_quant_roundtrip_error_bounded():
    """Per-head absmax int8 entries dequantize within one quantum."""
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 4, 16)) * 3.0
    q, scale = kv_lib.quant_entries(x)
    assert q.dtype == jnp.int8 and scale.shape == (6, 4)
    back = q.astype(jnp.float32) * scale[..., None]
    quantum = np.asarray(scale)[..., None]
    assert (np.abs(np.asarray(back - x)) <= quantum + 1e-7).all()


# ---------------------------------------------------------------------------
# Engine scheduling
# ---------------------------------------------------------------------------

def test_engine_continuous_and_static_match_dense():
    """Every request served under continuous batching (and static waves)
    generates exactly the tokens the dense single-request path does —
    slots join/leave mid-flight without corrupting each other's pages."""
    cfg = _smoke()
    params = _params(cfg)
    eng = Engine(cfg, params, EngineConfig(num_slots=3, page_size=4,
                                           max_ctx=32, prefill_chunk=8))
    for static in (False, True):
        reqs = _requests(cfg, 6)
        eng.reset()
        stats = eng.run(reqs, static=static)
        assert stats["requests"] == 6
        for r in reqs:
            ref = generate(cfg, params, jnp.asarray([r.prompt], jnp.int32),
                           r.max_gen)[0].tolist()
            assert r.generated == ref, (static, r.rid)
        assert sorted(eng.free_pages) == list(range(1, eng.num_pages))


def test_engine_open_loop_arrivals_respected():
    cfg = _smoke()
    eng = Engine(cfg, _params(cfg), EngineConfig(num_slots=2, page_size=4,
                                                 max_ctx=32, prefill_chunk=8))
    reqs = _requests(cfg, 4)
    for i, r in enumerate(reqs):
        r.arrival = 0.03 * i
    eng.run(reqs)
    for r in reqs:
        assert r.t_admit >= r.arrival - 1e-6
        assert r.t_done >= r.t_first >= r.t_admit


def test_engine_page_exhaustion_serializes_and_recovers():
    """A pool sized for ~one request at a time forces head-of-line
    waiting: later requests admit only after earlier ones free their
    pages, outputs stay correct, and the free list fully recovers."""
    cfg = _smoke()
    params = _params(cfg)
    ecfg = EngineConfig(num_slots=2, page_size=4, max_ctx=24,
                        prefill_chunk=8, num_pages=1 + 7)  # max_pages=6
    eng = Engine(cfg, params, ecfg)
    reqs = [Request(rid=i, prompt=list(range(5 + i, 15 + i)), max_gen=6)
            for i in range(3)]
    eng.run(reqs)
    for r in reqs:
        ref = generate(cfg, params, jnp.asarray([r.prompt], jnp.int32),
                       r.max_gen)[0].tolist()
        assert r.generated == ref
    # with 7 usable pages and 4-page requests, at most one full request
    # holds pages at a time -> strictly serialized admissions
    assert reqs[1].t_admit >= reqs[0].t_done - 1e-6
    assert reqs[2].t_admit >= reqs[1].t_done - 1e-6
    assert sorted(eng.free_pages) == list(range(1, eng.num_pages))


def test_engine_int8_kv_greedy_close_to_f32():
    cfg = _smoke()
    params = _params(cfg)
    ecfg = dict(num_slots=2, page_size=8, max_ctx=40, prefill_chunk=8)
    outs = {}
    for quant in (None, "int8"):
        eng = Engine(cfg, params, EngineConfig(kv_quant=quant, **ecfg))
        reqs = _requests(cfg, 4, seed=11, max_prompt=24, max_gen=10)
        eng.run(reqs)
        outs[quant] = [r.generated for r in reqs]
    total = match = 0
    for a, b in zip(outs[None], outs["int8"]):
        assert len(a) == len(b)
        total += len(a)
        match += sum(int(x == y) for x, y in zip(a, b))
    assert match / total >= 0.9, (match, total, outs)


def _truncate(ref, eos_id=None, stop_seqs=()):
    """Expected engine output: dense greedy tokens cut at the first EOS /
    stop-sequence tail (inclusive), else the full max_gen run."""
    out = []
    for t in ref:
        out.append(t)
        if eos_id is not None and t == eos_id:
            break
        if any(stop and len(out) >= len(stop)
               and out[-len(stop):] == list(stop) for stop in stop_seqs):
            break
    return out


def test_engine_eos_retires_slot_and_admits_queue():
    """A request that emits eos_id retires early — its pages free up and
    the next queued request is admitted into the single slot; both outputs
    match the dense greedy path truncated at EOS."""
    cfg = _smoke()
    params = _params(cfg)
    reqs = _requests(cfg, 3, seed=7, max_prompt=16, max_gen=8)
    refs = [generate(cfg, params, jnp.asarray([r.prompt], jnp.int32),
                     r.max_gen)[0].tolist() for r in reqs]
    # an EOS the first request emits mid-generation, so the early retire
    # actually happens (not just the max_gen bound)
    long0 = next(ref for ref in refs if len(ref) >= 4)
    eos = long0[len(long0) // 2]
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=1, page_size=4, max_ctx=32,
                              prefill_chunk=8, eos_id=eos))
    eng.run(reqs)
    truncated_any = False
    for r, ref in zip(reqs, refs):
        want = _truncate(ref, eos_id=eos)
        assert r.generated == want, (r.rid, r.generated, want)
        truncated_any |= len(want) < len(ref)
        assert r.t_done >= 0    # every queued request was served
    assert truncated_any
    # retirement returned every page (stopped slots leak nothing)
    assert sorted(eng.free_pages) == list(range(1, eng.num_pages))
    # single slot: the queue only drains through retirement
    order = sorted(reqs, key=lambda r: r.t_admit)
    for a, b in zip(order, order[1:]):
        assert b.t_admit >= a.t_done - 1e-6


def test_engine_eos_on_first_token_retires_from_prefill():
    """EOS as the very first generated token: the slot retires straight
    from PREFILL without ever entering DECODE."""
    cfg = _smoke()
    params = _params(cfg)
    req = Request(rid=0, prompt=list(range(3, 13)), max_gen=6)
    ref = generate(cfg, params, jnp.asarray([req.prompt], jnp.int32),
                   6)[0].tolist()
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=2, page_size=4, max_ctx=24,
                              prefill_chunk=8, eos_id=ref[0]))
    eng.run([req])
    assert req.generated == [ref[0]]
    assert sorted(eng.free_pages) == list(range(1, eng.num_pages))


def test_engine_stop_sequence_retires():
    cfg = _smoke()
    params = _params(cfg)
    req = Request(rid=0, prompt=list(range(5, 17)), max_gen=8)
    ref = generate(cfg, params, jnp.asarray([req.prompt], jnp.int32),
                   8)[0].tolist()
    stop = tuple(ref[2:4])      # tail hit after the 4th token
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=2, page_size=4, max_ctx=32,
                              prefill_chunk=8, stop_seqs=(stop,)))
    eng.run([req])
    want = _truncate(ref, stop_seqs=(stop,))
    assert req.generated == want and len(want) <= 4
    assert sorted(eng.free_pages) == list(range(1, eng.num_pages))


def test_engine_rejects_unsupported_archs():
    for arch in ("gemma2-9b",      # sliding-window ring buffer
                 "xlstm-350m"):    # recurrent mixer
        cfg = configs.get_smoke(arch)
        with pytest.raises(NotImplementedError):
            Engine(cfg, _params(cfg), EngineConfig())
    cfg = configs.get_smoke("seamless-m4t-large-v2")   # enc-dec
    with pytest.raises(NotImplementedError, match="decode_stack"):
        Engine(cfg, None, EngineConfig())


# ---------------------------------------------------------------------------
# pad_cache hardening
# ---------------------------------------------------------------------------

def test_ensure_capacity_raises_on_undersized_cache():
    cfg = _smoke()
    params = _params(cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 6), 0, cfg.vocab)
    _, cache = jax.jit(lm.make_prefill_step(cfg))(params, {"tokens": tokens})
    # unpadded prefill cache (depth 6) cannot absorb 4 decode writes
    with pytest.raises(ValueError, match="silently clamp"):
        ensure_capacity(cache, 10)
    padded = pad_cache(cache, 10)
    assert ensure_capacity(padded, 10) is padded
    # ring-buffer leaves (depth == window) are exempt by design
    win = {"k": jnp.zeros((1, 4, 2, 8)), "v": jnp.zeros((1, 4, 2, 8))}
    ensure_capacity(win, 100, window=4)


# ---------------------------------------------------------------------------
# Checkpoint -> serve
# ---------------------------------------------------------------------------

def test_restore_params_reads_trailing_leaves(tmp_path):
    cfg = _smoke()
    params = _params(cfg, seed=4)
    opt = optim.make("adam", lr=1e-3)
    tree = {"opt": opt.init(params), "params": params}
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, tree, blocking=True)
    restored, step = cm.restore_params(None, lm.abstract_params(cfg))
    assert step == 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # bare params tree (offset 0) loads through the same path
    cm2 = CheckpointManager(str(tmp_path / "bare"))
    cm2.save(2, params, blocking=True)
    restored2, _ = cm2.restore_params(None, lm.abstract_params(cfg))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # wrong arch -> loud mismatch, not silently wrong weights
    wrong = configs.get_smoke("llama-60m").with_(d_model=64, head_dim=32,
                                                 d_ff=128)
    with pytest.raises(StructureMismatch):
        cm.restore_params(None, lm.abstract_params(wrong))


@pytest.mark.parametrize("codec", ["f32", "int8"])
def test_train_checkpoint_serve_roundtrip(tmp_path, codec):
    """GWT-trained weights (f32 and int8 moment substrates) restored by
    the serving engine produce bitwise-identical logits to a direct
    forward pass, and engine greedy decoding equals dense generate."""
    cfg = _smoke()
    params = _params(cfg, seed=6)
    opt = optim.make("gwt", lr=1e-2, level=2, state_codec=codec)
    ostate = opt.init(params)
    data = SyntheticLM(cfg.vocab, 16, 2, seed=5)
    step_fn = jax.jit(lm.make_train_step(cfg, opt))
    for i in range(4):
        params, ostate, _ = step_fn(params, ostate, data.batch(i))
    cm = CheckpointManager(str(tmp_path))
    cm.save(4, {"opt": ostate, "params": params}, blocking=True)

    restored, _ = cm.restore_params(None, lm.abstract_params(cfg))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tokens = data.batch(9)["tokens"][:1, :12]
    direct, _, _ = lm.forward(cfg, params, tokens)
    served, _, _ = lm.forward(cfg, restored, tokens)
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(served))

    eng = Engine.from_checkpoint(cfg, str(tmp_path),
                                 EngineConfig(num_slots=2, page_size=4,
                                              max_ctx=24, prefill_chunk=8))
    req = Request(rid=0, prompt=tokens[0].tolist(), max_gen=5)
    eng.run([req])
    ref = generate(cfg, restored, tokens, 5)[0].tolist()
    assert req.generated == ref


def test_pretrain_finetune_serve_roundtrip(tmp_path):
    """Full fine-tune loop: pretrain → LoRA fine-tune (the checkpoint
    holds a {'base','lora'} tree) → serve.  The engine auto-detects the
    fine-tune from the checkpoint's run metadata, merges the adapters at
    load, and its greedy output equals dense generate on merged weights;
    explicit merge_lora=True covers checkpoints without the metadata."""
    from repro.models import lora
    RANK, ALPHA = 4, 8.0
    cfg = _smoke()
    params = _params(cfg, seed=8)
    data = SyntheticLM(cfg.vocab, 16, 2, seed=13)

    # pretrain a couple of steps, then fine-tune adapters on the result
    opt = optim.make("adam", lr=1e-2)
    step_fn = jax.jit(lm.make_train_step(cfg, opt))
    ostate = opt.init(params)
    for i in range(2):
        params, ostate, _ = step_fn(params, ostate, data.batch(i))

    tree = lora.inject(params, RANK, jax.random.key(3))
    fopt = lora.wrap_optimizer(optim.make("gwt", lr=1e-2, level=2))
    fstate = fopt.init(tree)
    ft_step = jax.jit(lora.make_train_step(lm, cfg, fopt,
                                           rank=RANK, alpha=ALPHA))
    for i in range(3):
        tree, fstate, _ = ft_step(tree, fstate, data.batch(10 + i))
    # adapters actually moved: the merged model differs from the base
    merged = lora.merge(tree, ALPHA, RANK)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(merged),
                               jax.tree.leaves(params)))

    cm = CheckpointManager(
        str(tmp_path), run_meta={"finetune": {"mode": "lora", "rank": RANK,
                                              "alpha": ALPHA}})
    cm.save(3, {"opt": fstate, "params": tree}, blocking=True)

    tokens = data.batch(20)["tokens"][:1, :12]
    ref = generate(cfg, merged, tokens, 5)[0].tolist()
    ecfg = EngineConfig(num_slots=2, page_size=4, max_ctx=24,
                        prefill_chunk=8)
    # (a) auto-detected from run metadata
    eng = Engine.from_checkpoint(cfg, str(tmp_path), ecfg)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(eng.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    req = Request(rid=0, prompt=tokens[0].tolist(), max_gen=5)
    eng.run([req])
    assert req.generated == ref
    # (b) explicit merge on a metadata-less checkpoint
    cm2 = CheckpointManager(str(tmp_path / "bare"))
    cm2.save(3, {"opt": fstate, "params": tree}, blocking=True)
    eng2 = Engine.from_checkpoint(cfg, str(tmp_path / "bare"), ecfg,
                                  merge_lora=True, lora_rank=RANK,
                                  lora_alpha=ALPHA)
    req2 = Request(rid=1, prompt=tokens[0].tolist(), max_gen=5)
    eng2.run([req2])
    assert req2.generated == ref
    # (c) a plain checkpoint must NOT be disturbed by the new path
    with pytest.raises(StructureMismatch):
        Engine.from_checkpoint(cfg, str(tmp_path / "bare"), ecfg,
                               merge_lora=False)
