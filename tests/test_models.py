"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED config, runs forward + one GWT train step +
(where applicable) prefill/decode, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.models import encdec, lm

ARCHS = configs.ARCH_IDS


def _batch(cfg, key, B=2, S=64):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.mrope_sections:
        batch["mrope_positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
    if cfg.arch_class == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, S // 4, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = configs.get_smoke(arch)
    B, S = 2, 64
    batch = _batch(cfg, key, B, S)
    mod = encdec if cfg.arch_class == "encdec" else lm
    params = mod.init(cfg, key)
    if cfg.arch_class == "encdec":
        enc = encdec.encode(cfg, params, batch["enc_embeds"])
        logits, _ = encdec.decode_stack(cfg, params, batch["tokens"], enc)
    else:
        logits, _, aux = lm.forward(cfg, params, batch["tokens"],
                                    mrope_positions=batch.get(
                                        "mrope_positions"))
        assert np.isfinite(float(aux))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    opt = optim.make("gwt", lr=1e-3, level=2)
    st = opt.init(params)
    ts = jax.jit(mod.make_train_step(cfg, opt, accum_steps=2))
    params2, st, metrics = ts(params, st, batch)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if configs.get_smoke(a).arch_class
                                  != "encdec"])
def test_decode_matches_full_forward(arch, key):
    """Incremental KV/recurrent-cache decode == sliced full forward."""
    cfg = configs.get_smoke(arch)
    B, S = 2, 32
    if cfg.window:
        S = max(S, cfg.window)  # ring-buffer handoff needs S % window == 0
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    params = lm.init(cfg, key)
    mrope = (jnp.broadcast_to(jnp.arange(S), (3, B, S))
             if cfg.mrope_sections else None)
    full_logits, _, _ = lm.forward(cfg, params, tokens, mode="train",
                                   mrope_positions=mrope)

    prefix = S - 4
    pre_tok = tokens[:, :prefix]
    pre_mrope = mrope[:, :, :prefix] if mrope is not None else None
    logits_p, cache, _ = lm.forward(cfg, params, pre_tok, mode="prefill",
                                    mrope_positions=pre_mrope)
    from repro.launch.serve import pad_cache
    cache = pad_cache(cache, S, window=cfg.window)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(full_logits[:, prefix - 1], np.float32),
        atol=0.05, rtol=0.05)
    for t in range(prefix, S):
        step_mrope = (jnp.broadcast_to(jnp.asarray(t), (3, B, 1))
                      if cfg.mrope_sections else None)
        logits_d, cache, _ = lm.forward(
            cfg, params, tokens[:, t:t + 1], mode="decode", caches=cache,
            mrope_positions=step_mrope)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            atol=0.05, rtol=0.05, err_msg=f"{arch} decode step {t}")


def test_encdec_decode_matches_teacher_forcing(key):
    cfg = configs.get_smoke("seamless-m4t-large-v2")
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    enc_embeds = jax.random.normal(key, (B, 8, cfg.d_model), jnp.bfloat16)
    params = encdec.init(cfg, key)
    enc = encdec.encode(cfg, params, enc_embeds)
    full_logits, _ = encdec.decode_stack(cfg, params, tokens, enc)

    prefix = S - 3
    logits_p, cache = encdec.decode_stack(cfg, params, tokens[:, :prefix],
                                          enc, mode="prefill")
    from repro.launch.serve import pad_cache
    # pad only the self-attention cache; cross KV must stay at enc length
    cache = {"dec": {"self": pad_cache(cache["dec"]["self"], S),
                     "cross": cache["dec"]["cross"]},
             "pos": cache["pos"]}
    for t in range(prefix, S):
        logits_d, cache = encdec.decode_stack(
            cfg, params, tokens[:, t:t + 1], None, mode="decode",
            caches=cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            atol=0.05, rtol=0.05)


def test_param_builder_trees_consistent():
    """init / axes / abstract trees share structure & shapes (one builder)."""
    for arch in ARCHS:
        cfg = configs.get_smoke(arch)
        mod = encdec if cfg.arch_class == "encdec" else lm
        abst = mod.abstract_params(cfg)
        axes = mod.param_axes(cfg)
        ini = mod.init(cfg, jax.random.key(0))
        s_a = jax.tree_util.tree_structure(abst)
        from repro.models.layers import Axes
        s_x = jax.tree_util.tree_structure(
            axes, is_leaf=lambda x: isinstance(x, Axes))
        s_i = jax.tree_util.tree_structure(ini)
        assert s_a == s_i, arch
        assert str(s_x) == str(s_a), arch
        for sds, arr in zip(jax.tree.leaves(abst), jax.tree.leaves(ini)):
            assert sds.shape == arr.shape and sds.dtype == arr.dtype, arch
        for sds, ax in zip(jax.tree.leaves(abst),
                           jax.tree.leaves(axes, is_leaf=lambda x:
                                           isinstance(x, Axes))):
            assert len(ax.names) == len(sds.shape), (arch, ax, sds.shape)


def test_local_attention_equals_masked_direct(key):
    """Block-local sliding-window path == direct path with window mask."""
    from repro.models import attention
    cfg = configs.get_smoke("gemma2-9b")
    B, S = 2, 96  # 3 blocks of window=32
    q = jax.random.normal(key, (B, S, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 4, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 4, 16))
    o_block = attention._local_block_attn(q, k, v, window=32, cap=0.0)
    o_direct = attention._direct_attn(q, k, v, causal_offset=0, window=32,
                                      cap=0.0)
    np.testing.assert_allclose(np.asarray(o_block), np.asarray(o_direct),
                               atol=2e-2, rtol=2e-2)


def test_flash_attention_equals_direct(key):
    from repro.models import attention
    B, S, H, hd = 1, 2048, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    o_flash = attention._flash_attn(q, k, v, q_chunk=256, kv_chunk=512)
    o_direct = attention._direct_attn(q, k, v, causal_offset=0, window=0,
                                      cap=0.0)
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_direct),
                               atol=2e-2, rtol=2e-2)


def test_moe_expert_padding_is_semantically_invisible(key):
    """expert_padding pads WEIGHTS only (EP divisibility); routed outputs
    must be bit-identical to the unpadded config given identical weights."""
    from repro.models import moe as moe_lib
    from repro.models.layers import Builder
    cfg0 = configs.get_smoke("qwen2-moe-a2.7b").with_(expert_padding=0)
    cfg4 = cfg0.with_(expert_padding=4)
    b = Builder("init", key, jnp.bfloat16)
    p0 = moe_lib.moe_init(Builder("init", key, jnp.bfloat16), cfg0)
    p4 = moe_lib.moe_init(Builder("init", key, jnp.bfloat16), cfg4)
    # copy the real experts' weights into the padded arrays
    E = cfg0.n_experts
    for k in ("w_gate", "w_up", "w_down"):
        p4[k] = p4[k].at[:E].set(p0[k])
    p4["router"] = p0["router"]
    if "shared" in p0:
        p4["shared"] = p0["shared"]
    x = jax.random.normal(key, (2, 16, cfg0.d_model), jnp.bfloat16)
    y0, aux0 = moe_lib.moe_apply(p0, cfg0, x)
    y4, aux4 = moe_lib.moe_apply(p4, cfg4, x)
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y4, np.float32), atol=1e-5)
    np.testing.assert_allclose(float(aux0), float(aux4), rtol=1e-6)
