"""Optimizer shoot-out (paper Table II proxy): Adam vs GaLore vs APOLLO vs
Fira vs MUON vs GWT-2/GWT-3 on a small LLaMA, identical data/schedule.

    PYTHONPATH=src python examples/compare_optimizers.py [--steps 120]

Prints final loss + optimizer-state memory per method — the paper's claim
under test: GWT matches or beats the low-rank baselines at equal-or-lower
memory (Table II) and stays close to full-rank Adam.
"""

import argparse

import jax

from repro import configs, optim
from repro.core.gwt import state_memory_bytes
from repro.data.pipeline import make_source
from repro.models import lm
from repro.optim.schedules import warmup_cosine
from repro.runtime.fault_tolerance import TrainLoop

CFG = configs.LLAMA["llama-60m"].with_(n_layers=4, d_model=256, n_heads=4,
                                       n_kv_heads=4, head_dim=64, d_ff=688,
                                       vocab=2048, name="llama-tiny")

METHODS = [
    ("adam", {"lr_scale": 0.25}),          # Adam needs the smaller lr (paper)
    ("muon", {}),
    ("galore", {"rank_frac": 0.25, "alpha": 0.25, "update_gap": 50}),
    ("apollo", {"rank_frac": 0.25, "alpha": 1.0, "update_gap": 50}),
    ("fira", {"rank_frac": 0.25, "alpha": 0.25, "update_gap": 50}),
    ("gwt", {"level": 2, "alpha": 0.25}),
    ("gwt", {"level": 3, "alpha": 0.25}),
    ("gwt", {"level": 2, "alpha": 0.25, "host": "adam_mini"}),
    ("gwt", {"level": 2, "alpha": 0.25, "host": "muon"}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    rows = []
    for name, kw in METHODS:
        kw = dict(kw)
        lr = 0.01 * kw.pop("lr_scale", 1.0)
        tag = name
        if name == "gwt":
            tag = f"gwt-{kw.get('level')}({kw.get('host', 'adam')})"
        key = jax.random.key(0)
        params = lm.init(CFG, key)
        opt = optim.make(name, lr=warmup_cosine(lr, args.steps), **kw)
        opt_state = opt.init(params)
        data = make_source("synthetic", CFG.vocab, args.seq, args.batch)
        step = jax.jit(lm.make_train_step(CFG, opt))
        loop = TrainLoop(step, None, data, log_every=10**9)
        _, _, losses = loop.run(params, opt_state, num_steps=args.steps)
        level = kw.get("level", 0) if name == "gwt" else 0
        host = kw.get("host", "adam") if name == "gwt" else "adam"
        mem = state_memory_bytes(params, level, host=host)["total_bytes"]
        k = max(1, len(losses) // 10)
        final = sum(losses[-k:]) / k
        rows.append((tag, final, mem / 2**20))
        print(f"{tag:22s} final_loss={final:8.4f} state={mem/2**20:7.1f}MiB")

    print("\nmethod                  final-loss   opt-state-MiB")
    for tag, loss, mem in sorted(rows, key=lambda r: r[1]):
        print(f"{tag:22s} {loss:10.4f} {mem:12.1f}")


if __name__ == "__main__":
    main()
