"""Quickstart: GWT-Adam vs full-rank Adam on a tiny LLaMA (CPU, ~2 min).

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end-to-end: config → init → GWT optimizer →
train loop → memory accounting.  Shows the paper's headline: comparable
loss at a fraction of the optimizer-state memory (Table I / Fig. 1).
"""

import jax

from repro import configs, optim
from repro.optim.engine import state_bytes
from repro.data.pipeline import make_source
from repro.models import lm
from repro.optim.schedules import warmup_cosine
from repro.runtime.fault_tolerance import TrainLoop

STEPS = 60
CFG = configs.LLAMA["llama-60m"].with_(n_layers=4, d_model=256, n_heads=4,
                                       n_kv_heads=4, head_dim=64, d_ff=688,
                                       vocab=2048, name="llama-tiny")


def run(optimizer_name: str, **kw):
    key = jax.random.key(0)
    params = lm.init(CFG, key)
    opt = optim.make(optimizer_name, lr=warmup_cosine(0.01, STEPS), **kw)
    opt_state = opt.init(params)
    data = make_source("synthetic", CFG.vocab, 128, 16, seed=0)
    step = jax.jit(lm.make_train_step(CFG, opt))
    loop = TrainLoop(step, None, data, log_every=20)
    _, _, losses = loop.run(params, opt_state, num_steps=STEPS)
    # exact per-optimizer accounting (eval_shape over the real init)
    return losses[-1], state_bytes(opt, params) / 2**20


if __name__ == "__main__":
    results = {}
    for name, kw in [("adam", {}), ("gwt", {"level": 2}),
                     ("gwt", {"level": 3})]:
        tag = name if name == "adam" else f"gwt-{kw['level']}"
        print(f"=== {tag} ===")
        loss, mem = run(name, **kw)
        results[tag] = (loss, mem)
    print("\noptimizer  final-loss  opt-state-MiB")
    for tag, (loss, mem) in results.items():
        print(f"{tag:9s}  {loss:10.4f}  {mem:10.1f}")
    adam_loss = results["adam"][0]
    gwt_loss = results["gwt-2"][0]
    print(f"\nGWT-2 keeps loss within {(gwt_loss/adam_loss - 1)*100:+.1f}% of "
          f"Adam at {results['gwt-2'][1]/results['adam'][1]*100:.0f}% of its "
          f"optimizer memory")
