"""Batched serving example: prefill a batch of prompts, decode with KV
caches, verify incremental decode against the full forward pass.

    PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-9b]

Uses the reduced smoke config of any assigned arch (default exercises the
sliding-window ring-buffer cache path).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import generate
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    if cfg.arch_class == "encdec":
        raise SystemExit("decoder-only example; see tests for enc-dec decode")
    key = jax.random.key(0)
    params = lm.init(cfg, key)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    out = generate(cfg, params, tokens, args.gen)
    print(f"[{cfg.name}] generated {out.shape}")

    # cross-check: greedy decode must match argmax of the full forward pass
    full = tokens
    for i in range(args.gen):
        logits, _, _ = lm.forward(cfg, params, full, mode="train")
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        full = jnp.concatenate([full, nxt], axis=1)
    ref = full[:, args.prompt_len:]
    match = float((ref == out).mean())
    print(f"incremental-vs-full greedy agreement: {match*100:.1f}%")
    assert match > 0.9, "KV-cache decode diverged from full forward"
    print("OK")


if __name__ == "__main__":
    main()
