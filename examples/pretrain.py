"""End-to-end pre-training driver (assignment deliverable b): train a ~100M
LLaMA with GWT-Adam for a few hundred steps with checkpoint/restart.

    PYTHONPATH=src python examples/pretrain.py \
        [--model llama-130m] [--steps 300] [--batch 16] [--seq 256]

This is the paper's Table II setting scaled to the CPU container: same
module-wise GWT policy, lr=0.01, alpha=0.25, cosine schedule, NL limiter.
On a pod, the identical step function lowers under the production mesh
(see repro.launch.dryrun).  SIGTERM-safe; re-run with the same --ckpt-dir
to resume.
"""

import argparse

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama-130m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--level", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_pretrain_ckpt")
    ap.add_argument("--data", default="synthetic")
    args = ap.parse_args()

    train_cli.main([
        "--arch", args.model, "--optimizer", "gwt",
        "--level", str(args.level), "--alpha", "0.25", "--lr", "0.01",
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--data", args.data,
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100", "--resume",
    ])


if __name__ == "__main__":
    main()
