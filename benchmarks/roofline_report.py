"""Roofline table generator (assignment deliverable g).

    PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_baseline.json

Per (arch × shape) single-pod cell: the three roofline terms, the dominant
bottleneck, MODEL_FLOPS = 6·N(active)·D vs parsed HLO FLOPs (useful-compute
ratio), and a one-line "what would move the bottleneck" note.
"""

from __future__ import annotations

import json
import sys

from repro import configs
from repro.models import encdec, lm

PEAK = 197e12


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·D for train; 2·N_active·tokens for decode/prefill."""
    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    mod = encdec if cfg.arch_class == "encdec" else lm
    params = mod.abstract_params(cfg)
    n_total = sum(p.size for p in jax.tree.leaves(params)) \
        if False else sum(int(_np_prod(p.shape))
                          for p in _leaves(params))
    # active params: subtract non-routed expert fraction
    if cfg.n_experts and cfg.top_k:
        expert_per_layer = 3 * cfg.d_model * cfg.d_ff_expert
        moe_layers = sum("moe" in k for k in cfg.pattern) \
            * max(cfg.n_periods, 1) or cfg.n_layers
        routed = expert_per_layer * cfg.n_experts * moe_layers
        active = expert_per_layer * cfg.top_k * moe_layers
        n_active = n_total - routed + active
    else:
        n_active = n_total
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def _leaves(t):
    import jax
    return jax.tree.leaves(t)


def _np_prod(s):
    out = 1
    for x in s:
        out *= x
    return out


def advice(cell: dict) -> str:
    r = cell["roofline"]
    b = r["bottleneck"]
    cb = r.get("collective_breakdown", {})
    if b == "collective":
        top = max(cb, key=cb.get) if cb else "?"
        return (f"dominant wire op {top} ({cb.get(top, 0)/1e9:.1f}GB/dev): "
                "overlap with compute / reduce precision / defer to "
                "post-accumulation")
    if b == "memory":
        return ("HBM-bound: fuse optimizer transform (Pallas gwt_adam), "
                "bf16 score buffers, larger microbatch to amortize weights")
    return "compute-bound: near roofline; raise arithmetic intensity"


def main():
    import jax  # noqa: F401  (model_flops uses tree utils)
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.json"
    cells = json.load(open(path))
    single = [c for c in cells if not c["multi_pod"]]
    print("| arch | shape | compute s | memory s | collective s | bottleneck"
          " | MODEL_FLOPS/HLO | fits | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in single:
        if c["status"] == "skip":
            print(f"| {c['arch']} | {c['shape']} | — | — | — | skip | — | — "
                  f"| {c['reason'][:48]} |")
            continue
        if c["status"] != "ok":
            print(f"| {c['arch']} | {c['shape']} | — | — | — | ERROR | — | —"
                  f" | {c.get('error', '')[:60]} |")
            continue
        r = c["roofline"]
        mf = model_flops(c["arch"], c["shape"])
        hlo_total = r["parsed_dot_flops_per_device"] * c["n_chips"]
        ratio = mf / hlo_total if hlo_total else 0.0
        print(f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3f} | "
              f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
              f"{r['bottleneck']} | {ratio:.2f} | "
              f"{'Y' if c['fits_hbm'] else 'N'} | {advice(c)[:64]} |")

    # Roofline fractions by workload kind.  Train/prefill: compute-vs-
    # lower-bound (MFU-style).  Decode (1 token/step): compute≈0 by
    # construction — the meaningful roofline is the MEMORY term (cache
    # streaming is the physical floor), so report memory/lower-bound.
    def frac_rows(kinds, num_key):
        rows = []
        for c in single:
            if c["status"] != "ok" or configs.SHAPES[c["shape"]].kind \
                    not in kinds:
                continue
            r = c["roofline"]
            lb = max(r["compute_s"], r["memory_s"], r["collective_s"])
            if lb > 0:
                rows.append((r[num_key] / lb, c["arch"], c["shape"]))
        rows.sort()
        return rows

    tp = frac_rows(("train", "prefill"), "compute_s")
    dc = frac_rows(("decode",), "memory_s")
    if tp:
        print(f"\ntrain/prefill roofline fraction (compute/lower-bound): "
              f"median={tp[len(tp)//2][0]:.2f}")
        print("  worst 3:", [(f"{f:.3f}", a, s) for f, a, s in tp[:3]])
        print("  best 3:", [(f"{f:.3f}", a, s) for f, a, s in tp[-3:]])
    if dc:
        print(f"decode streaming fraction (memory/lower-bound): "
              f"median={dc[len(dc)//2][0]:.2f}")
        print("  worst 3:", [(f"{f:.3f}", a, s) for f, a, s in dc[:3]])
        print("  best 3:", [(f"{f:.3f}", a, s) for f, a, s in dc[-3:]])


if __name__ == "__main__":
    main()
