"""Post-compile HLO text analyzer → roofline terms.

Why text parsing: ``compiled.cost_analysis()`` counts a ``while`` body ONCE
(verified empirically — scan FLOPs = unroll/N), so any scan-over-layers or
grad-accumulation loop would be undercounted N×.  This module parses the
partitioned HLO, builds the computation call graph, multiplies each
computation
by the product of enclosing while trip counts (parsed from the loop-condition
constant), and attributes:

* dot/convolution FLOPs (2 · prod(out) · prod(contracting)),
* fusion/op HBM bytes (operands + outputs of top-level ops — matching XLA's
  post-fusion "bytes accessed" convention),
* collective bytes with per-kind wire conventions:
    all-reduce 2·size, all-gather (out−in), reduce-scatter in,
    all-to-all in, collective-permute in.

All numbers are PER-DEVICE (the module is the per-device SPMD program).
Hardware constants: TPU v5e-like (assignment): 197 TFLOP/s bf16, 819 GB/s
HBM, 50 GB/s/link ICI.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_CALL_ATTR_RE = re.compile(
    r"(?:condition|body|calls|to_apply|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _parse_shape(type_str: str) -> List[Tuple[str, List[int]]]:
    """'bf16[8,128]{1,0}' or tuple '(f32[2], s32[])' -> [(dtype, dims), ...]"""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(x) for x in dims.split(",") if x]))
    return out


def _shape_bytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims) if dims else _DTYPE_BYTES[dt]
               for dt, dims in _parse_shape(type_str))


@dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    multiplier: float = 0.0  # times executed; filled by propagation


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self.op_types: Dict[str, str] = {}  # op name -> type str (shapes)
        self._parse(text)
        self._propagate()

    # -- parsing ----------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for line in text.splitlines():
            stripped = line.strip()
            header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->", stripped)
            if header and stripped.endswith("{"):
                cur = Computation(header.group(2))
                self.computations[cur.name] = cur
                if header.group(1):
                    self.entry = cur.name
                continue
            if cur is None or stripped.startswith("}"):
                if stripped.startswith("}"):
                    cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, type_str, kind = m.groups()
            cur.ops.append(Op(name, kind, type_str, stripped))
            self.op_types[name] = type_str

    # -- trip counts ------------------------------------------------------
    def _trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name)
        if comp is None:
            return 1
        consts = [int(m.group(1)) for op in comp.ops
                  for m in re.finditer(r"constant\((\d+)\)", op.line)]
        return max(consts) if consts else 1

    def _propagate(self):
        for c in self.computations.values():
            c.multiplier = 0.0
        entry = self.computations.get(self.entry)
        if entry is None:  # fall back: treat all as executed once
            for c in self.computations.values():
                c.multiplier = 1.0
            return
        seen = set()

        def visit(comp: Computation, mult: float):
            comp.multiplier += mult
            key = comp.name
            if key in seen and comp.multiplier > 1e12:
                return
            for op in comp.ops:
                for attr in _CALL_ATTR_RE.finditer(op.line):
                    names = [n.strip().lstrip("%")
                             for n in attr.group(1).split(",")]
                    if op.kind == "while":
                        mw = re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
                                       op.line)
                        if mw:
                            trips = self._trip_count(mw.group(1))
                            visit_once(mw.group(2), mult * trips)
                            visit_once(mw.group(1), mult * (trips + 1))
                        break
                    for n in names:
                        visit_once(n, mult)

        def visit_once(name: str, mult: float):
            comp = self.computations.get(name)
            if comp is not None:
                visit(comp, mult)

        visit(entry, 1.0)
        # computations never reached (dead or unhandled refs): count once
        for c in self.computations.values():
            if c.multiplier == 0.0:
                c.multiplier = 1.0

    # -- analyses ---------------------------------------------------------

    def _operand_shapes(self, op: Op) -> List[str]:
        """Type strings of the op's operands (resolved by name)."""
        args = re.search(r"\(([^)]*)\)", op.line[op.line.index(op.kind):])
        if not args:
            return []
        names = re.findall(r"%([\w.\-]+)", args.group(1))
        return [self.op_types[n] for n in names if n in self.op_types]

    def dot_flops(self) -> float:
        total = 0.0
        for comp in self.computations.values():
            if comp.multiplier == 0:
                continue
            for op in comp.ops:
                if op.kind not in ("dot", "convolution"):
                    continue
                out = _parse_shape(op.type_str)
                out_elems = sum(math.prod(d) if d else 1 for _, d in out)
                contract = 1
                mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
                if mc:
                    lhs_types = self._operand_shapes(op)
                    if lhs_types:
                        lhs = _parse_shape(lhs_types[0])
                        if lhs:
                            dims = lhs[0][1]
                            idxs = [int(x) for x in mc.group(1).split(",") if x]
                            contract = math.prod(dims[i] for i in idxs) or 1
                total += comp.multiplier * 2.0 * out_elems * contract
        return total

    def hbm_bytes_tpu_model(self) -> float:
        """HBM traffic under a TPU-fusion model.

        The dry-run compiles on the CPU backend, whose HLO barely fuses —
        counting every top-level op's operands+outputs over-states TPU HBM
        traffic ~50× (measured).  On TPU, elementwise chains (norms, rope,
        softmax, residual adds) fuse into their matmul neighbours, so the
        irreducible traffic is: matmul/conv operands+outputs, collective
        payloads, explicit gather/scatter/cache-update ops, and program
        arguments/outputs (optimizer/param streams) — which is what we sum,
        trip-scaled.  This is a *lower-bound-flavored* estimate; the full
        op-granularity sum is reported as ``hbm_bytes_upper`` for contrast.
        """
        matmul = {"dot", "convolution"}
        coll = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start"}
        slice_like = {"dynamic-slice", "gather"}
        update_like = {"dynamic-update-slice", "scatter"}
        total = 0.0
        for comp in self.computations.values():
            if comp.multiplier == 0:
                continue
            for op in comp.ops:
                if op.kind in matmul or op.kind in coll:
                    # full operands read + output written
                    b = _shape_bytes(op.type_str)
                    b += sum(_shape_bytes(t) for t in self._operand_shapes(op))
                elif op.kind in slice_like:
                    # only the sliced/gathered window moves, not the base
                    b = 2 * _shape_bytes(op.type_str)
                elif op.kind in update_like:
                    # in-place on TPU: read update + write the same window
                    ops_t = self._operand_shapes(op)
                    b = 2 * _shape_bytes(ops_t[1]) if len(ops_t) > 1 \
                        else _shape_bytes(op.type_str)
                else:
                    continue
                total += comp.multiplier * b
        return total

    def hbm_bytes(self) -> float:
        """Post-fusion bytes: operands + outputs of top-level ops, skipping
        pure control/metadata ops and fused subcomputations (their caller's
        fusion op carries the bytes)."""
        skip = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "while", "conditional", "call", "after-all",
                "partition-id", "replica-id"}
        fused_subs = set()
        for comp in self.computations.values():
            for op in comp.ops:
                if op.kind == "fusion":
                    m = re.search(r"calls=%?([\w.\-]+)", op.line)
                    if m:
                        fused_subs.add(m.group(1))
        total = 0.0
        for comp in self.computations.values():
            if comp.name in fused_subs or comp.multiplier == 0:
                continue
            for op in comp.ops:
                if op.kind in skip:
                    continue
                b = _shape_bytes(op.type_str)
                b += sum(_shape_bytes(t) for t in self._operand_shapes(op))
                total += comp.multiplier * b
        return total

    def collective_bytes(self) -> Dict[str, float]:
        """Wire bytes per collective kind (per device), trip-scaled."""
        out: Dict[str, float] = defaultdict(float)
        for comp in self.computations.values():
            if comp.multiplier == 0:
                continue
            for op in comp.ops:
                kind = op.kind.replace("-start", "")
                if kind not in ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"):
                    continue
                out_b = _shape_bytes(op.type_str)
                in_b = sum(_shape_bytes(t) for t in self._operand_shapes(op))
                if kind == "all-reduce":
                    wire = 2.0 * in_b
                elif kind == "all-gather":
                    wire = max(out_b - in_b, 0)
                else:
                    wire = in_b
                out[kind] += comp.multiplier * wire
        return dict(out)


def analyze(hlo_text: str, *, n_chips: int,
            cost_analysis: Optional[dict] = None,
            io_bytes: float = 0.0) -> dict:
    mod = HloModule(hlo_text)
    coll = mod.collective_bytes()
    coll_total = sum(coll.values())
    flops = mod.dot_flops()
    bytes_hbm = mod.hbm_bytes_tpu_model() + io_bytes
    res = {
        "parsed_dot_flops_per_device": flops,
        "parsed_hbm_bytes_per_device": bytes_hbm,
        "hbm_bytes_upper_per_device": mod.hbm_bytes(),
        "collective_bytes_per_device": coll_total,
        "collective_breakdown": coll,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": coll_total / ICI_BW,
        "n_chips": n_chips,
    }
    terms = {"compute": res["compute_s"], "memory": res["memory_s"],
             "collective": res["collective_s"]}
    res["bottleneck"] = max(terms, key=terms.get)
    res["step_time_lower_bound_s"] = max(terms.values())
    if cost_analysis:
        res["xla_cost_flops_unscaled"] = cost_analysis.get("flops", 0.0)
        res["xla_cost_bytes_unscaled"] = cost_analysis.get("bytes accessed", 0.0)
    return res
