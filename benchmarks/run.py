"""Benchmark harness — one function per paper table.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows.  Model-quality proxies use
tiny configs + the synthetic pipeline (offline container); memory numbers
are exact accounting; op microbenchmarks are wall-clock on CPU.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _time(fn, *args, n=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------
# Table I — memory & complexity of optimizer states
# ---------------------------------------------------------------------------

def table1_memory(quick: bool):
    from repro import configs
    from repro.core.gwt import state_memory_bytes
    from repro.models import lm
    cfg = configs.LLAMA["llama-60m"]
    params = lm.abstract_params(cfg)
    mn = sum(p.size for p in jax.tree.leaves(params))
    for name, level, expect in [("full_adam", 0, "2mn"),
                                ("gwt2", 2, "mn/2"), ("gwt3", 3, "mn/4")]:
        mem = state_memory_bytes(params, level)
        emit(f"table1/{name}_state_MiB", 0.0,
             f"{mem['total_bytes']/2**20:.1f}MiB expect~{expect}")
    emit("table1/params_M", 0.0, f"{mn/1e6:.1f}M")


# ---------------------------------------------------------------------------
# Table II — pre-training quality proxy (final loss, tiny LLaMA)
# ---------------------------------------------------------------------------

def table2_pretrain(quick: bool):
    from repro import configs, optim
    from repro.data.pipeline import SyntheticLM
    from repro.models import lm
    from repro.optim.schedules import warmup_cosine
    steps = 30 if quick else 80
    cfg = configs.LLAMA["llama-60m"].with_(
        n_layers=3, d_model=192, n_heads=4, n_kv_heads=4, head_dim=48,
        d_ff=512, vocab=1024)
    methods = [("adam", "adam", dict(lr=warmup_cosine(0.0025, steps))),
               ("galore_1_4", "galore", dict(lr=warmup_cosine(0.01, steps),
                                             rank_frac=0.25, update_gap=25)),
               ("apollo_1_4", "apollo", dict(lr=warmup_cosine(0.01, steps),
                                             rank_frac=0.25, update_gap=25)),
               ("fira_1_4", "fira", dict(lr=warmup_cosine(0.01, steps),
                                         rank_frac=0.25, update_gap=25)),
               ("muon", "muon", dict(lr=warmup_cosine(0.01, steps))),
               ("gwt2", "gwt", dict(lr=warmup_cosine(0.01, steps), level=2)),
               ("gwt3", "gwt", dict(lr=warmup_cosine(0.01, steps), level=3))]
    for tag, name, kw in methods:
        opt = optim.make(name, **kw)
        params = lm.init(cfg, jax.random.key(0))
        st = opt.init(params)
        data = SyntheticLM(cfg.vocab, 64, 16, seed=0)
        step = jax.jit(lm.make_train_step(cfg, opt))
        t0 = time.perf_counter()
        loss = None
        for i in range(steps):
            b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, st, m = step(params, st, b)
            loss = float(m["loss"])
        dt = (time.perf_counter() - t0) / steps * 1e6
        emit(f"table2/{tag}_final_loss", dt, f"{loss:.4f}")


# ---------------------------------------------------------------------------
# Table III — update-op throughput (the optimizer step itself)
# ---------------------------------------------------------------------------

def table3_throughput(quick: bool):
    from repro import optim
    m, n = (1024, 4096) if not quick else (256, 1024)
    params = {"mlp": {"w": jax.random.normal(jax.random.key(0), (m, n),
                                             jnp.float32)}}
    grads = {"mlp": {"w": jax.random.normal(jax.random.key(1), (m, n),
                                            jnp.float32) * 0.01}}
    for tag, name, kw in [("adam", "adam", {}),
                          ("galore_1_4", "galore", {"rank_frac": 0.25,
                                                    "update_gap": 200}),
                          ("apollo_1_4", "apollo", {"rank_frac": 0.25,
                                                    "update_gap": 200}),
                          ("gwt2", "gwt", {"level": 2}),
                          ("gwt3", "gwt", {"level": 3})]:
        opt = optim.make(name, lr=1e-3, **kw)
        st = opt.init(params)
        upd = jax.jit(opt.update)
        p2, s2 = upd(grads, st, params)  # includes any step-0 SVD
        us = _time(lambda g, s, p: upd(g, s, p)[0], grads, s2, p2, n=20)
        emit(f"table3/{tag}_update", us, f"{m}x{n}")
    # GaLore's SVD refresh step (the O(mn^2) cost the paper avoids):
    opt = optim.make("galore", lr=1e-3, rank_frac=0.25, update_gap=1)
    st = opt.init(params)
    upd = jax.jit(opt.update)
    p2, s2 = upd(grads, st, params)
    us = _time(lambda g, s, p: upd(g, s, p)[0], grads, s2, p2, n=5)
    emit("table3/galore_refresh_step", us, "SVD every step")


# ---------------------------------------------------------------------------
# Table IV — sequence-length robustness proxy
# ---------------------------------------------------------------------------

def table4_seqlen(quick: bool):
    from repro import configs, optim
    from repro.data.pipeline import SyntheticLM
    from repro.models import lm
    from repro.optim.schedules import warmup_cosine
    steps = 20 if quick else 50
    cfg = configs.LLAMA["llama-60m"].with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512)
    for seq in ((64, 128) if quick else (64, 128, 256)):
        for tag, name, kw in [("gwt2", "gwt", {"level": 2}),
                              ("galore", "galore",
                               {"rank_frac": 0.25, "update_gap": 25})]:
            opt = optim.make(name, lr=warmup_cosine(0.01, steps), **kw)
            params = lm.init(cfg, jax.random.key(0))
            st = opt.init(params)
            data = SyntheticLM(cfg.vocab, seq, 8, seed=0)
            step = jax.jit(lm.make_train_step(cfg, opt))
            loss = None
            for i in range(steps):
                b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
                params, st, m = step(params, st, b)
                loss = float(m["loss"])
            emit(f"table4/{tag}_seq{seq}_final_loss", 0.0, f"{loss:.4f}")


# ---------------------------------------------------------------------------
# Table XI — per-model memory estimates (weights + optimizer states, bf16)
# ---------------------------------------------------------------------------

def table11_memory_estimate(quick: bool):
    from repro import configs
    from repro.core.gwt import state_memory_bytes
    from repro.models import lm
    models = ["llama-60m", "llama-130m"] if quick else \
        ["llama-60m", "llama-130m", "llama-350m", "llama-1b"]
    for name in models:
        cfg = configs.LLAMA[name]
        params = lm.abstract_params(cfg)
        w = sum(p.size for p in jax.tree.leaves(params)) * 2 / 2**30
        for tag, level in [("adam", 0), ("gwt2", 2), ("gwt3", 3)]:
            st = state_memory_bytes(params, level)["total_bytes"] / 2**30
            emit(f"table11/{name}_{tag}", 0.0,
                 f"weights={w:.2f}G states={st:.2f}G")


# ---------------------------------------------------------------------------
# Table XII — GWT level sweep: state memory + fused-update throughput
# ---------------------------------------------------------------------------

def table12_levels(quick: bool):
    from repro import configs
    from repro.core.gwt import state_memory_bytes
    from repro.kernels.gwt_adam import ops as gops
    from repro.models import lm
    cfg = configs.LLAMA["llama-60m"]
    params = lm.abstract_params(cfg)
    m, n = (512, 4096) if not quick else (128, 1024)
    g = jax.random.normal(jax.random.key(0), (m, n))
    for level in (1, 2, 3, 4, 5):
        st = {"m": jnp.zeros((m, n >> level)), "v": jnp.zeros((m, n >> level))}
        us = _time(lambda gg, ss: gops.fused_update(
            gg, ss, jnp.int32(1), level=level, impl="jnp")[0], g, st, n=20)
        mem = state_memory_bytes(params, level)["total_bytes"] / 2**20
        emit(f"table12/gwt{level}", us, f"state={mem:.1f}MiB")


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (fused vs unfused + HBM-traffic model)
# ---------------------------------------------------------------------------

def kernels_bench(quick: bool):
    from repro.core import haar
    from repro.kernels.gwt_adam import ref as gref
    from repro.optim import hosts
    m, n, level = (512, 4096, 2) if not quick else (128, 1024, 2)
    g = jax.random.normal(jax.random.key(0), (m, n))
    ms = jnp.zeros((m, n >> level))
    vs = jnp.zeros((m, n >> level))

    fused = jax.jit(lambda g, m_, v_: gref.gwt_adam_tile(g, m_, v_,
                                                         level=level))
    us_f = _time(lambda *a: fused(*a)[0], g, ms, vs, n=20)
    emit("kernel/gwt_adam_fused_ref", us_f, f"{m}x{n} l{level}")

    host = hosts.adam()

    def unfused(g, m_, v_):
        a, ds = haar.haar_forward(g, level)
        pre, dsc, lrm, st = host.update(a, {"m": m_, "v": v_}, jnp.int32(0))
        tilde = [d * haar.detail_scale_upsample(dsc, level, level - i)
                 for i, d in enumerate(ds)]
        return haar.haar_inverse(pre, tilde)

    us_u = _time(jax.jit(unfused), g, ms, vs, n=20)
    emit("kernel/gwt_adam_unfused", us_u, f"fused_speedup={us_u/us_f:.2f}x")

    # backend sweep through the portability layer: the same fused_update
    # entry point the optimizer uses, per available impl on this platform
    # ('pallas' only where supported — REPRO_KERNEL_IMPL / MeshContext
    # route the same knob at launch time).
    from repro.kernels.gwt_adam import ops as gops
    impls = ["jnp", "interpret"]
    if jax.default_backend() == "tpu":   # platform support, not the
        impls.append("pallas")           # REPRO_KERNEL_IMPL override
    st = {"m": ms, "v": vs}
    for impl in impls:
        us_i = _time(lambda gg, ss: gops.fused_update(
            gg, ss, jnp.int32(1), level=level, impl=impl)[0], g, st,
            n=5 if impl == "interpret" else 20)
        emit(f"kernel/gwt_adam_impl_{impl}", us_i, f"{m}x{n} l{level}")

    # fusion HBM-traffic model (what matters on TPU): elements per grad el.
    l = level
    fused_traffic = 2 + 4 / 2 ** l
    unfused_traffic = 6 + 10 / 2 ** l
    emit("kernel/gwt_adam_traffic_model", 0.0,
         f"fused={fused_traffic:.2f} unfused={unfused_traffic:.2f} "
         f"el/el -> {unfused_traffic/fused_traffic:.2f}x bw win")


# ---------------------------------------------------------------------------
# Trace-size / compile-time: per-leaf loop vs bucketed engine.
#
# The payoff of the leaf-plan engine: the jitted update trace holds one scan
# body per (rule, shape) bucket instead of one unrolled update graph per
# leaf, so jaxpr equation count stays ~flat as layers are added while the
# per-leaf loop grows linearly.  Writes BENCH_trace_cpu.json next to this
# file (the ROADMAP multi-backend-sweep baseline).
# ---------------------------------------------------------------------------

def _layered_params(n_layers: int, d: int = 64, f: int = 128, vocab: int = 256):
    k = jax.random.key(0)
    p = {"embed": jax.random.normal(jax.random.fold_in(k, 999),
                                    (vocab, d)) * 0.02,
         "norm": jnp.ones((d,))}
    for i in range(n_layers):
        kk = jax.random.fold_in(k, i)
        p[f"layer_{i:02d}"] = {
            "attn": {"wq": jax.random.normal(jax.random.fold_in(kk, 0),
                                             (d, d)) * 0.05,
                     "wo": jax.random.normal(jax.random.fold_in(kk, 1),
                                             (d, d)) * 0.05},
            "mlp": {"w1": jax.random.normal(jax.random.fold_in(kk, 2),
                                            (d, f)) * 0.05,
                    "w2": jax.random.normal(jax.random.fold_in(kk, 3),
                                            (f, d)) * 0.05}}
    return p


def _trace_cell(opt_name, kw, n_layers, impl=None):
    """(jaxpr_eqns, lower+compile seconds) for one optimizer update step."""
    from repro import optim
    okw = dict(kw)
    if impl is not None:
        okw["impl"] = impl
    opt = optim.make(opt_name, lr=1e-3, **okw)
    params = _layered_params(n_layers)
    grads = jax.tree.map(lambda p: p * 0.01, params)
    st = opt.init(params)
    eqns = len(jax.make_jaxpr(opt.update)(grads, st, params).eqns)
    t0 = time.perf_counter()
    jax.jit(opt.update).lower(grads, st, params).compile()
    return eqns, time.perf_counter() - t0


def trace_bench(quick: bool):
    import json
    import os
    layer_counts = (2, 8) if quick else (2, 4, 8, 16)
    out = {"layer_counts": list(layer_counts), "cells": {}}
    for tag, name, kw, impls in [
            ("gwt2", "gwt", {"level": 2}, ["jnp"] if quick
             else ["jnp", "interpret"]),
            ("adam", "adam", {}, [None])]:
        for impl in impls:
            itag = f"{tag}_{impl}" if impl else tag
            for bucketed, btag in ((False, "perleaf"), (True, "bucketed")):
                eqns_row, secs_row = [], []
                for nl in layer_counts:
                    eqns, secs = _trace_cell(name, dict(kw, bucketed=bucketed),
                                             nl, impl)
                    eqns_row.append(eqns)
                    secs_row.append(round(secs, 3))
                out["cells"][f"{itag}_{btag}"] = {"jaxpr_eqns": eqns_row,
                                                 "compile_s": secs_row}
                emit(f"trace/{itag}_{btag}_compile_us_L{layer_counts[-1]}",
                     secs_row[-1] * 1e6,
                     f"eqns={eqns_row} compile_s={secs_row}")
    # growth check: bucketed eqn count must grow sublinearly in layer count
    lo, hi = layer_counts[0], layer_counts[-1]
    for cell, data in out["cells"].items():
        if cell.endswith("bucketed"):
            e = data["jaxpr_eqns"]
            ratio = e[-1] / max(e[0], 1)
            linear = hi / lo
            emit(f"trace/{cell}_growth", 0.0,
                 f"{ratio:.2f}x over {lo}->{hi} layers "
                 f"(per-leaf would be ~{linear:.0f}x)")
    # quick (CI smoke) runs don't overwrite the committed full baseline
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_trace_cpu_quick.json" if quick
                        else "BENCH_trace_cpu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    emit("trace/json", 0.0, path)


# ---------------------------------------------------------------------------
# Train-step runtime: steps/sec + tokens/sec of the pipelined donated
# TrainLoop vs the pre-PR eager loop, and peak-live-bytes of the donated
# vs undonated train step (XLA buffer assignment).  Writes
# BENCH_step_cpu.json; --quick additionally gates against the committed
# baseline (>20% steps/sec regression on the headline cell fails CI).
# ---------------------------------------------------------------------------

STEP_HEADLINE = "gwt_jnp"


def _loop_steps_per_sec(loop, params, st, steps, repeats=3):
    """Best-of-N steps/sec for one warmed loop (compile excluded by a
    prior untimed run; params/state copied per run — the pipelined loop
    donates its inputs)."""
    import jax
    best = 0.0
    for _ in range(repeats):
        p, s = jax.tree.map(lambda a: a.copy(), (params, st))
        t0 = time.perf_counter()
        p, s, _ = loop.run(p, s, num_steps=steps)
        jax.block_until_ready(p)
        best = max(best, steps / (time.perf_counter() - t0))
    return best


def _fused_write_live_bytes():
    """Peak live bytes of the fused-write (megakernel) dataflow vs the
    staged pipeline, from XLA buffer assignment on a representative
    stacked ``(L, m, n)`` bucket.

    Fused: ONE program takes ``(g, p, m, v, prev_norm)`` and emits
    ``(new_p, new_norm, new_m, new_v)`` with ``p``/state donated — g̃
    lives only as an in-program temp.  Staged (the pre-megakernel
    dataflow): stage A runs the DWT+Adam core and EMITS g̃ as a program
    output; stage B applies limiter+step+write.  The staged peak charges
    stage A with ``p`` and ``prev_norm`` held live across the launch
    boundary — exactly the buffers fusion lets the scheduler drop.  Both
    sides are measured on the tiled jnp oracle (``impl='jnp'``), which
    mirrors the kernel's dataflow 1:1 (tested bitwise); the interpret
    backend's Pallas *emulation* allocates per-grid-point scratch that a
    real lowering doesn't, so it would measure emulator overhead, not the
    algorithm."""
    from repro.core import limiter
    from repro.kernels.gwt_adam import ops as gops
    from repro.optim.engine import live_update_bytes

    L, m, n, level = 4, 256, 2048, 2
    g = jnp.zeros((L, m, n), jnp.float32)
    p = jnp.zeros((L, m, n), jnp.float32)
    st = {"m": jnp.zeros((L, m, n >> level), jnp.float32),
          "v": jnp.zeros((L, m, n >> level), jnp.float32)}
    pn = jnp.zeros((L,), jnp.float32)
    kw = dict(lr_t=jnp.float32(1e-3), alpha=0.25, weight_decay=0.0,
              gamma=1.01, use_limiter=True, level=level)

    fused = jax.jit(
        lambda g, p, st, pn: gops.fused_write_update(
            g, p, st, jnp.int32(2), pn, impl="jnp", **kw),
        donate_argnums=(1, 2, 3)).lower(g, p, st, pn).compile()

    stage_a = jax.jit(
        lambda g, st: gops.fused_update(g, st, jnp.int32(2), level=level,
                                        impl="jnp"),
        donate_argnums=(1,)).lower(g, st).compile()

    def _stage_b(gt, p, pn, lr_mult):
        def one(gtl, pl, pnl):
            gl, nl = limiter.limit(gtl, pnl, gamma=1.01)
            step = jnp.float32(1e-3) * lr_mult * 0.25
            new_p = pl.astype(jnp.float32) - step * gl.astype(jnp.float32)
            return new_p.astype(pl.dtype), nl
        return jax.vmap(one)(gt, p, pn)

    # donate p only: g̃ has no same-shaped output left to alias (new_p
    # pairs with p), so donating it would just trip the unusable-donation
    # warning without changing the accounting.
    stage_b = jax.jit(_stage_b, donate_argnums=(1,)).lower(
        g, p, pn, jnp.float32(1.0)).compile()

    fused_live = live_update_bytes(fused)
    live_a = live_update_bytes(stage_a)
    live_b = live_update_bytes(stage_b)
    if None in (fused_live, live_a, live_b):
        return None
    held = p.size * p.dtype.itemsize + pn.size * pn.dtype.itemsize
    staged_live = max(live_a + held, live_b)
    return {"bucket": [L, m, n], "level": level,
            "fused_live_bytes": fused_live,
            "staged_live_bytes": staged_live,
            "staged_stage_a_bytes": live_a,
            "staged_stage_b_bytes": live_b,
            "staged_held_across_boundary_bytes": held,
            "ratio": round(fused_live / staged_live, 4)}


def step_bench(quick: bool):
    import json
    import os

    from repro import configs, optim
    from repro.data.pipeline import SyntheticLM
    from repro.models import lm
    from repro.optim.engine import live_update_bytes, state_bytes
    from repro.runtime.fault_tolerance import TrainLoop

    cfg = configs.get_smoke("llama-60m")
    B, S = 1, 64
    chunk = 20                      # superstep length = log cadence
    silent = lambda s: None  # noqa: E731
    out = {"config": {"arch": cfg.name, "batch": B, "seq": S,
                      "chunk": chunk},
           "cells": {}}
    cells = [("gwt", "jnp", "f32"), ("gwt", "interpret", "f32"),
             ("gwt", "jnp", "int8"),
             ("adam", None, "f32"), ("galore", None, "f32")]
    for name, impl, cdc in cells:
        tag = f"{name}_{impl}" if impl else name
        if cdc != "f32":
            tag += f"_{cdc}"
        interp = impl == "interpret"
        steps = (chunk if quick else 2 * chunk) if interp \
            else (2 * chunk if quick else 3 * chunk)
        kw = {"level": 2, "impl": impl} if name == "gwt" else \
            ({"rank_frac": 0.25, "update_gap": 2 * steps}
             if name == "galore" else {})
        opt = optim.make(name, lr=1e-3, state_codec=cdc, **kw)
        params = lm.init(cfg, jax.random.key(0))
        st = opt.init(params)
        data = SyntheticLM(cfg.vocab, S, B, seed=0)
        b0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

        # peak live bytes: XLA buffer assignment of the jitted train step,
        # donated vs not — donation must alias params+opt_state through.
        plain = jax.jit(lm.make_train_step(cfg, opt)) \
            .lower(params, st, b0).compile()
        donated = lm.make_train_step(cfg, opt, donate=True) \
            .lower(params, st, b0).compile()
        live_plain, live_don = (live_update_bytes(plain),
                                live_update_bytes(donated))
        sb = state_bytes(opt, params)

        # pre-PR loop: per-step dispatch + float(loss) sync, sync fetch,
        # no donation.
        eager_loop = TrainLoop(jax.jit(lm.make_train_step(cfg, opt)), None,
                               data, log_every=10, log=silent,
                               pipelined=False)
        eager_loop.run(*jax.tree.map(lambda a: a.copy(), (params, st)),
                       num_steps=2)  # warm the jit cache
        eager = _loop_steps_per_sec(eager_loop, params, st, steps,
                                    repeats=1 if interp else 3)

        # pipelined loop: donated scan-over-chunk supersteps, prefetched
        # batches, loss fetched once per chunk.
        pipe_loop = TrainLoop(lm.make_train_step(cfg, opt), None, data,
                              log_every=chunk, max_chunk=chunk, log=silent)
        pipe_loop.run(*jax.tree.map(lambda a: a.copy(), (params, st)),
                      num_steps=chunk)  # compile the superstep
        pipe = _loop_steps_per_sec(pipe_loop, params, st, steps,
                                   repeats=1 if interp else 3)

        cell = {"steps_per_sec_eager": round(eager, 2),
                "steps_per_sec_pipelined": round(pipe, 2),
                "tokens_per_sec_pipelined": round(pipe * B * S, 1),
                "speedup": round(pipe / eager, 3),
                "opt_state_bytes": sb,
                "peak_live_bytes_plain": live_plain,
                "peak_live_bytes_donated": live_don}
        out["cells"][tag] = cell
        emit(f"step/{tag}", 1e6 / pipe,
             f"pipelined={pipe:.1f}steps/s eager={eager:.1f} "
             f"speedup={pipe/eager:.2f}x "
             f"live={live_don}B vs {live_plain}B undonated")
        if live_plain is not None and live_don is not None \
                and live_don >= live_plain:
            emit(f"step/{tag}_donation_ERROR", 0.0,
                 f"donated peak live {live_don} >= undonated {live_plain}")

    # compound substrate win: GWT moment subspaces x blocked-int8 codec vs
    # the full-Adam f32 reference (both measured on this config's real
    # init — the gate trips if either side's accounting drifts)
    full_adam = out["cells"]["adam"]["opt_state_bytes"]
    q8 = out["cells"]["gwt_jnp_int8"]["opt_state_bytes"]
    ratio = full_adam / q8
    out["compression"] = {"full_adam_f32_bytes": full_adam,
                          "gwt_int8_bytes": q8,
                          "ratio": round(ratio, 2)}
    if ratio < 10.0:
        emit("step/compression_ERROR", 0.0,
             f"gwt+int8 opt state {q8}B only {ratio:.1f}x under full-Adam "
             f"f32 {full_adam}B (< 10x)")
    else:
        emit("step/compression_gate", 0.0,
             f"gwt+int8 {q8}B = {ratio:.1f}x under full-Adam f32 "
             f"{full_adam}B (ok)")

    # fused-write megakernel gate: the one-launch grad→wavelet→limit→write
    # program must peak strictly below the staged two-launch pipeline
    # (where g̃ crosses the launch boundary and p waits out stage A).
    fw = _fused_write_live_bytes()
    out["fused_write"] = fw
    if fw is None:
        emit("step/fusedwrite_ERROR", 0.0,
             "memory_analysis unavailable; fused-write live bytes unmeasured")
    elif fw["fused_live_bytes"] >= fw["staged_live_bytes"]:
        emit("step/fusedwrite_ERROR", 0.0,
             f"fused-write peak live {fw['fused_live_bytes']}B >= staged "
             f"{fw['staged_live_bytes']}B")
    else:
        emit("step/fusedwrite_gate", 0.0,
             f"fused-write peak live {fw['fused_live_bytes']}B = "
             f"{fw['ratio']:.2f}x of staged {fw['staged_live_bytes']}B (ok)")

    hl = out["cells"][STEP_HEADLINE]
    out["headline"] = {"cell": STEP_HEADLINE, "speedup": hl["speedup"]}
    here = os.path.dirname(os.path.abspath(__file__))
    committed = os.path.join(here, "BENCH_step_cpu.json")
    if quick and os.path.exists(committed):
        with open(committed) as f:
            base = json.load(f)["cells"].get(STEP_HEADLINE)
        if base:
            ref = base["steps_per_sec_pipelined"]
            now = hl["steps_per_sec_pipelined"]
            if now < 0.8 * ref:
                emit("step/regression_ERROR", 0.0,
                     f"pipelined {now:.1f} steps/s < 80% of committed "
                     f"{ref:.1f} (gwt_jnp cell)")
            else:
                emit("step/regression_gate", 0.0,
                     f"{now:.1f} steps/s vs committed {ref:.1f} (ok)")
    path = os.path.join(here, "BENCH_step_cpu_quick.json" if quick
                        else "BENCH_step_cpu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    emit("step/json", 0.0, path)


# ---------------------------------------------------------------------------
# Optimizer-state accounting: the full family x codec matrix on the real
# llama-60m (abstract params, eval_shape only — no allocation), writing
# BENCH_state_cpu.json.  Gates (always): int8 strictly shrinks every
# moment-bearing family, and eval_shape bytes are self-consistent across
# codecs (q + scales never exceed ~27% of the f32 moment slots).
# ---------------------------------------------------------------------------

def state_bench(quick: bool):
    import json
    import os

    from repro import configs, optim
    from repro.models import lm
    from repro.optim.engine import state_bytes

    cfg = configs.get_smoke("llama-60m") if quick \
        else configs.get_config("llama-60m")
    params = lm.abstract_params(cfg)
    p_bytes = sum(l.size * jnp.dtype(l.dtype).itemsize
                  for l in jax.tree_util.tree_leaves(params))
    families = [("adam", {}), ("adam_mini", {}), ("muon", {}), ("sgd", {}),
                ("galore", {"rank_frac": 0.25}),
                ("apollo", {"rank_frac": 0.25}),
                ("fira", {"rank_frac": 0.25}),
                ("gwt", {"level": 2})]
    out = {"config": {"arch": cfg.name, "params_bytes": p_bytes},
           "cells": {}}
    for name, kw in families:
        row = {}
        for cdc in ("f32", "int8"):
            opt = optim.make(name, lr=1e-3, state_codec=cdc, **kw)
            row[cdc] = state_bytes(opt, params)
        row["int8_saving"] = round(row["f32"] / row["int8"], 3)
        out["cells"][name] = row
        emit(f"state/{name}", 0.0,
             f"f32={row['f32']}B int8={row['int8']}B "
             f"({row['int8_saving']}x)")
        if row["int8"] >= row["f32"]:
            emit(f"state/{name}_codec_ERROR", 0.0,
                 f"int8 {row['int8']}B does not shrink f32 {row['f32']}B")
    full_adam = out["cells"]["adam"]["f32"]
    q8 = out["cells"]["gwt"]["int8"]
    out["compound"] = {"full_adam_f32_bytes": full_adam,
                       "gwt_int8_bytes": q8,
                       "ratio": round(full_adam / q8, 2)}
    emit("state/compound", 0.0,
         f"gwt+int8 {q8}B = {full_adam / q8:.1f}x under full-Adam f32 "
         f"{full_adam}B (params {p_bytes}B)")
    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "BENCH_state_cpu_quick.json" if quick
                        else "BENCH_state_cpu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    emit("state/json", 0.0, path)


# ---------------------------------------------------------------------------
# Sharded train path (DESIGN.md §3): DP all-reduce wire bytes (exact f32 vs
# wavelet-compressed) and steps/sec of the mesh-aware step on a simulated
# 8-device mesh.  The measurement runs in a SUBPROCESS with its own
# --xla_force_host_platform_device_count=8 (this process keeps its real
# single device); writes BENCH_shard_cpu.json.  Gates (always): the f8
# level-2 wire format must move ≥2× fewer bytes than exact f32 on the real
# llama-60m gradient tree; --quick additionally fails on a >20% steps/sec
# regression vs the committed baseline.
# ---------------------------------------------------------------------------

SHARD_WIRE_GATE = 2.0


def _shard_worker(quick: bool):
    """Runs inside the 8-device subprocess; prints one JSON line."""
    import json

    from repro import compat, configs, optim
    from repro.data.pipeline import SyntheticLM
    from repro.distributed import sharding as shr
    from repro.distributed.compression import DPReduceSpec, tree_wire_bytes
    from repro.models import lm
    from repro.runtime.context import MeshContext
    from repro.runtime.fault_tolerance import TrainLoop

    # -- wire accounting on the REAL llama-60m gradient tree (abstract) ----
    grads_abs = lm.abstract_params(configs.LLAMA["llama-60m"])
    full = tree_wire_bytes(grads_abs, None)
    wire = {"exact_f32": {"bytes_per_step": full, "ratio": 1.0}}
    for tag, level, dt in [("bf16_l2", 2, jnp.bfloat16),
                           ("bf16_l3", 3, jnp.bfloat16),
                           ("f8_l2", 2, jnp.float8_e4m3fn),
                           ("f8_l4", 4, jnp.float8_e4m3fn)]:
        b = tree_wire_bytes(grads_abs, DPReduceSpec(level=level,
                                                    detail_dtype=dt))
        wire[tag] = {"bytes_per_step": b, "ratio": round(full / b, 3)}

    # -- steps/sec through the pipelined loop, 8-device sim ----------------
    cfg = configs.get_smoke("llama-60m")
    B, S, chunk = 16, 32, 8
    steps = chunk * (2 if quick else 4)
    silent = lambda s: None  # noqa: E731
    cells = {}
    for tag, mesh_shape, dp in [
            ("nomesh_1dev", None, None),
            ("mesh8_exact", (8,), DPReduceSpec(level=2, detail_dtype=None)),
            ("mesh8_compressed", (8,), DPReduceSpec(level=2))]:
        ctx = MeshContext.create(
            mesh=None if mesh_shape is None
            else compat.make_mesh(mesh_shape, ("data",)))
        opt = optim.make("gwt", lr=1e-3, level=2)
        params = lm.init(cfg, jax.random.key(0))
        st = opt.init(params)
        data = SyntheticLM(cfg.vocab, S, B, seed=0)
        shardings = None
        if mesh_shape is not None:
            b0 = data.batch(0)
            batch_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                         for k, v in b0.items()}
            shardings = shr.train_step_shardings(cfg, lm, batch_abs,
                                                 ctx.mesh,
                                                 shard_params=False)
            params = jax.device_put(params, shardings.params)
            st = jax.device_put(st, shr.replicated_like(st, ctx.mesh))
        step = lm.make_train_step(cfg, opt, ctx=ctx, dp_reduce=dp,
                                  shardings=shardings)
        loop = TrainLoop(step, None, data, log_every=chunk, max_chunk=chunk,
                         log=silent,
                         batch_shardings=None if shardings is None
                         else shardings.batch)
        with ctx.activate():
            loop.run(*jax.tree.map(lambda a: a.copy(), (params, st)),
                     num_steps=chunk)            # pay the compile
            sps = _loop_steps_per_sec(loop, params, st, steps,
                                      repeats=1 if quick else 2)
        cells[tag] = {"steps_per_sec": round(sps, 2),
                      "tokens_per_sec": round(sps * B * S, 1)}

    print(json.dumps({
        "config": {"arch": cfg.name, "batch": B, "seq": S, "chunk": chunk,
                   "devices": jax.device_count(),
                   "wire_model": "llama-60m full (abstract grads)"},
        "wire": wire, "cells": cells}))


def shard_bench(quick: bool):
    import json
    import os
    import subprocess

    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    cmd = [sys.executable, "-m", "benchmarks.run", "--shard-worker"]
    if quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1200)
    if r.returncode != 0:
        emit("shard/worker_ERROR", 0.0, (r.stdout + r.stderr)[-500:])
        return
    out = json.loads(r.stdout.strip().splitlines()[-1])

    for tag, w in out["wire"].items():
        emit(f"shard/wire_{tag}", 0.0,
             f"{w['bytes_per_step']/2**20:.1f}MiB/step {w['ratio']}x")
    for tag, c in out["cells"].items():
        emit(f"shard/{tag}", 1e6 / max(c["steps_per_sec"], 1e-9),
             f"{c['steps_per_sec']:.1f}steps/s "
             f"{c['tokens_per_sec']:.0f}tok/s")

    # acceptance gate: the committed artifact must show a ≥2× wire win at
    # level ≥ 2 (the f8 wire format; bf16 tops out at 2× asymptotically)
    ratio = out["wire"]["f8_l2"]["ratio"]
    if ratio < SHARD_WIRE_GATE:
        emit("shard/wire_gate_ERROR", 0.0,
             f"f8_l2 ratio {ratio} < {SHARD_WIRE_GATE}")
    else:
        emit("shard/wire_gate", 0.0,
             f"f8_l2 moves {ratio}x fewer bytes (gate >= "
             f"{SHARD_WIRE_GATE}x)")

    # steps/sec on the simulated mesh is telemetry, not a gate: 8 fake
    # devices are 8 threads contending for the same cores, and run-to-run
    # variance exceeds any sane regression band (observed ±40% on an
    # otherwise-idle container).  A throughput gate belongs with real
    # multi-chip numbers (ROADMAP); the wire gate above is deterministic.
    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "BENCH_shard_cpu_quick.json" if quick
                        else "BENCH_shard_cpu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    emit("shard/json", 0.0, path)


# ---------------------------------------------------------------------------
# Data subsystem: corpus-build CLI smoke + loader throughput (thread
# Prefetcher vs shared-memory process workers).  The tokenization-heavy
# source (on-the-fly BPE) is GIL-bound, so the thread path serializes with
# the consumer while process workers scale — GATED: process workers must
# not be slower than the thread path on that source.  The mmap corpus row
# is telemetry (pre-tokenized reads are too cheap for workers to matter).
# ---------------------------------------------------------------------------

FIXTURE_GLOB = "tests/fixtures/corpus/*.txt"
DATA_WORKER_GATE = 0.9   # process/thread tokens/sec floor (noise margin)


_FIXTURE_DIR = None


def _fixture_corpus() -> str:
    """Build the committed fixture corpus once per benchmark process
    (deterministic content: same text + tokenizer config -> same shards
    + hash).  A fresh ``mkdtemp`` per process — a fixed world-readable
    /tmp path would race concurrent benchmark runs and collide across
    users.  eval_fraction 0.1 keeps ~9 held-out seq-64 windows, enough
    for one full unique eval batch."""
    global _FIXTURE_DIR
    if _FIXTURE_DIR is None:
        import tempfile
        from repro.data.build_corpus import build
        _FIXTURE_DIR = tempfile.mkdtemp(prefix="repro_bench_corpus_")
        build(FIXTURE_GLOB, _FIXTURE_DIR, tokenizer_kind="bpe",
              vocab_size=512, eval_fraction=0.1)
    return _FIXTURE_DIR


def _drain_tokens_per_sec(pf, n_batches: int, warmup: int, seq: int,
                          batch: int, segments: int = 3) -> float:
    """Steady-state production rate, best of ``segments`` back-to-back
    timed drains.  The warmup must EXCEED the queue depth (otherwise the
    timed drain partly reads batches buffered during construction and
    flatters the slow path); best-of-segments because a 2-core CI box
    under frequency/background drift swings single-shot readings ~2×,
    and the gate below compares two such readings."""
    for _ in range(warmup):
        next(pf)
    best = 0.0
    for _ in range(segments):
        t0 = time.perf_counter()
        for _ in range(n_batches):
            next(pf)
        best = max(best, n_batches * batch * seq
                   / (time.perf_counter() - t0))
    return best


def data_bench(quick: bool):
    import json
    import os
    import subprocess
    import tempfile

    from repro.data.build_corpus import DOC_SEP, read_documents
    from repro.data.pipeline import (CorpusLM, Prefetcher, TokenizingTextLM)
    from repro.data.store import TokenStore
    from repro.data.workers import ProcessPrefetcher

    # corpus-build CLI smoke: the exact command the README quickstart gives
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with tempfile.TemporaryDirectory() as td:
        r = subprocess.run(
            [sys.executable, "-m", "repro.data.build_corpus",
             "--input", FIXTURE_GLOB, "--out", os.path.join(td, "c"),
             "--tokenizer", "bpe", "--vocab", "512", "--verify"],
            capture_output=True, text=True, cwd=repo,
            env=dict(os.environ, PYTHONPATH="src"), timeout=300)
    if r.returncode != 0 or "roundtrip=ok" not in r.stdout:
        emit("data/build_cli_ERROR", 0.0, (r.stdout + r.stderr)[-300:])
        return
    emit("data/build_cli", 0.0, r.stdout.strip().splitlines()[0][:80])

    corpus = _fixture_corpus()
    store = TokenStore(corpus)
    # B=32 keeps each BPE batch ~15-30ms of pure-python encode: heavy
    # enough that the per-batch IPC+copy overhead of the worker path is
    # noise next to the encode the workers parallelize
    S, B = 64, 32
    n = 10 if quick else 14
    depth = 4
    out = {"config": {"seq": S, "batch": B, "batches_timed": n,
                      "corpus_hash": store.corpus_hash[:12]}, "cells": {}}

    # mmap fast path (telemetry): pre-tokenized windows are nearly free
    mm = CorpusLM(corpus, S, B, seed=0)
    with Prefetcher(mm, depth=depth) as pf:
        mmap_tps = _drain_tokens_per_sec(pf, n, depth + 2, S, B)
    out["cells"]["corpus_mmap_thread"] = {"tokens_per_sec": round(mmap_tps)}
    emit("data/corpus_mmap_thread", 1e6 * B * S / mmap_tps,
         f"{mmap_tps:,.0f} tok/s (pre-tokenized mmap)")

    # tokenization-heavy source: on-the-fly BPE (GIL-bound pure python).
    # Thread and process paths are timed in INTERLEAVED segments (both
    # pipelines alive, best segment each): on a shared CI host,
    # sequential measurements live in different background-noise epochs
    # and the ratio gate flaps; interleaving samples both paths across
    # the same minutes.  The idle pipeline is quiescent meanwhile — its
    # bounded queue/slot ring fills and its producers block.
    text = DOC_SEP.join(read_documents(os.path.join(repo, FIXTURE_GLOB)))
    heavy = TokenizingTextLM(text, store.tokenizer, S, B, seed=0)
    # workers sized to the host: oversubscribing a small box (4 workers
    # on 2 cores) just context-switches away the win
    workers = 2 if quick else max(2, min(4, os.cpu_count() or 2))
    thread_tps = proc_tps = 0.0
    with Prefetcher(heavy, depth=depth) as pf, \
            ProcessPrefetcher(heavy, depth=2 * workers,
                              num_workers=workers) as pp:
        for _ in range(3 if quick else 4):
            # per-segment warmup >= the pipeline's buffer capacity: the
            # idle path refills its queue/slots during the other path's
            # segment, and timing those pre-buffered batches flatters a
            # path by buffer/n (measured: a phantom 1.5x thread "win")
            thread_tps = max(thread_tps,
                             _drain_tokens_per_sec(pf, n, depth + 1, S, B,
                                                   segments=1))
            proc_tps = max(proc_tps,
                           _drain_tokens_per_sec(pp, n, 2 * workers + 3,
                                                 S, B, segments=1))
    ratio = proc_tps / thread_tps
    out["cells"]["bpe_thread"] = {"tokens_per_sec": round(thread_tps)}
    out["cells"][f"bpe_process_{workers}w"] = {
        "tokens_per_sec": round(proc_tps), "vs_thread": round(ratio, 3)}
    emit("data/bpe_thread", 1e6 * B * S / thread_tps,
         f"{thread_tps:,.0f} tok/s (GIL-bound)")
    emit(f"data/bpe_process_{workers}w", 1e6 * B * S / proc_tps,
         f"{proc_tps:,.0f} tok/s ({ratio:.2f}x thread)")
    if ratio < DATA_WORKER_GATE:
        emit("data/worker_gate_ERROR", 0.0,
             f"process workers {proc_tps:,.0f} tok/s < "
             f"{DATA_WORKER_GATE}x thread {thread_tps:,.0f}")
    else:
        emit("data/worker_gate", 0.0,
             f"process {ratio:.2f}x thread (gate >= {DATA_WORKER_GATE}x)")

    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "BENCH_data_cpu_quick.json" if quick
                        else "BENCH_data_cpu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    emit("data/json", 0.0, path)


# ---------------------------------------------------------------------------
# Loss-curve harness (the paper's actual yardstick): train {gwt, adam,
# galore} smoke configs on the committed fixture corpus through the real
# pipelined TrainLoop with streaming held-out eval, record final/AUC train
# loss + eval perplexity curve to BENCH_curve_cpu.json.  Gate: every
# optimizer must LEARN (final loss well under its initial loss) — a
# numerics regression in any engine family trips it.
# ---------------------------------------------------------------------------

CURVE_LEARN_GATE = 0.9   # final loss must be < gate * initial loss
# (galore-1/4 on the 24-step --quick budget only reaches ~0.79× its
# initial loss — the gate is a did-it-learn-at-all tripwire, not a
# quality bar; quality lives in the committed per-cell numbers)

CURVE_TRACK_GATE = 1.25  # gwt2_int8 final loss must stay under this
# multiple of the gwt2 f32 final loss.  Measured on the fixture corpus
# the two runs land within run-to-run noise of each other (±~10% of
# final loss at the 24-step --quick budget, tighter at 72); a broken
# rounding stream stalls near the ~126-nat initial loss, far past any
# plausible noise band.

LORA_TRACK_GATE = 1.25   # gwt2-LoRA final loss vs adam-LoRA final loss.
# The fine-tune cells start from the adam cell's trained base, so the
# learn gate (a from-scratch tripwire) does not apply; what matters is
# that compressing the ADAPTER moments into wavelet subspaces tracks the
# uncompressed adapter run — same tolerance philosophy as int8 tracking.

LORA_RANK, LORA_ALPHA = 8, 16.0


def curve_bench(quick: bool):
    import json
    import os

    from repro import configs, optim
    from repro.data.eval import make_lm_evaluator
    from repro.data.pipeline import CorpusLM
    from repro.models import lm
    from repro.optim.schedules import warmup_cosine
    from repro.runtime.fault_tolerance import TrainLoop

    corpus = _fixture_corpus()
    steps = 24 if quick else 72
    S, B = 64, 8
    cfg = configs.LLAMA["llama-60m"].with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512)
    eval_every = max(steps // 3, 1)
    silent = lambda s: None  # noqa: E731
    train_src = CorpusLM(corpus, S, B, seed=0)
    out = {"config": {"arch": cfg.name, "seq": S, "batch": B,
                      "steps": steps, "eval_every": eval_every,
                      "corpus_hash": train_src.store.corpus_hash[:12]},
           "cells": {}}
    methods = [("gwt2", "gwt", dict(level=2)),
               ("gwt2_int8", "gwt", dict(level=2, state_codec="int8")),
               ("adam", "adam", {}),
               ("galore_1_4", "galore", dict(rank_frac=0.25,
                                             update_gap=steps))]
    base_params = None  # the adam cell's trained weights seed the LoRA cells
    for tag, name, kw in methods:
        opt = optim.make(name, lr=warmup_cosine(0.01, steps), **kw)
        params = lm.init(cfg, jax.random.key(0))
        st = opt.init(params)
        ev = make_lm_evaluator(cfg, lm,
                               CorpusLM(corpus, S, B, seed=0, split="eval"),
                               n_batches=4)
        loop = TrainLoop(lm.make_train_step(cfg, opt), None, train_src,
                         log_every=eval_every, max_chunk=8, log=silent,
                         evaluator=ev, eval_every=eval_every)
        t0 = time.perf_counter()
        trained, _, losses = loop.run(params, st, num_steps=steps)
        dt = time.perf_counter() - t0
        if tag == "adam":
            base_params = trained
        k = max(steps // 10, 1)
        cell = {"initial_loss": round(losses[0], 4),
                "final_loss": round(sum(losses[-k:]) / k, 4),
                "auc_loss": round(sum(losses) / len(losses), 4),
                "eval_curve": [(s, round(v, 4)) for s, v in ev.history],
                "final_eval_loss": round(ev.history[-1][1], 4),
                "steps_per_sec": round(steps / dt, 2)}
        out["cells"][tag] = cell
        emit(f"curve/{tag}", dt / steps * 1e6,
             f"final={cell['final_loss']} auc={cell['auc_loss']} "
             f"eval={cell['final_eval_loss']}")
        if cell["final_loss"] > CURVE_LEARN_GATE * cell["initial_loss"]:
            emit(f"curve/{tag}_learn_gate_ERROR", 0.0,
                 f"final {cell['final_loss']} > {CURVE_LEARN_GATE} * "
                 f"initial {cell['initial_loss']}")

    # quantized tracking gate: the int8 substrate must follow the f32 GWT
    # curve, not merely "learn" — stochastic rounding is unbiased, so the
    # two runs should land within noise of each other.
    f32_final = out["cells"]["gwt2"]["final_loss"]
    q8_final = out["cells"]["gwt2_int8"]["final_loss"]
    out["int8_tracking"] = {"final_loss_ratio": round(q8_final / f32_final,
                                                      4),
                            "bound": CURVE_TRACK_GATE}
    if q8_final > CURVE_TRACK_GATE * f32_final:
        emit("curve/int8_tracking_ERROR", 0.0,
             f"gwt2_int8 final loss {q8_final} > {CURVE_TRACK_GATE} * "
             f"gwt2 f32 final {f32_final}")
    else:
        emit("curve/int8_tracking_gate", 0.0,
             f"gwt2_int8 final {q8_final} vs f32 {f32_final} "
             f"(ratio {q8_final / f32_final:.3f} <= {CURVE_TRACK_GATE}, ok)")

    # ---- fine-tune cells: LoRA on the adam cell's trained base ----------
    # The paper claims GWT works for fine-tuning too: here the FROZEN base
    # carries zero optimizer state and only the adapters' Adam moments go
    # through the engine — "gwt2_lora" compresses those into wavelet
    # subspaces, "adam_lora" keeps them raw.  Same steps budget, fresh
    # data-order seed (a stand-in for a downstream corpus).
    from repro.models import lora
    ft_src = CorpusLM(corpus, S, B, seed=1)
    for tag, name, kw in [("gwt2_lora", "gwt", dict(level=2)),
                          ("adam_lora", "adam", {})]:
        inner = optim.make(name, lr=warmup_cosine(0.01, steps), **kw)
        opt = lora.wrap_optimizer(inner)
        # fresh buffers per cell: TrainLoop donates its input tree, which
        # would delete the shared base arrays for the next cell
        tree = lora.inject(jax.tree.map(jnp.copy, base_params), LORA_RANK,
                           jax.random.fold_in(jax.random.key(0), 777))
        st = opt.init(tree)
        ev = make_lm_evaluator(cfg, lora.loss_module(lm, LORA_ALPHA,
                                                     LORA_RANK),
                               CorpusLM(corpus, S, B, seed=0, split="eval"),
                               n_batches=4)
        loop = TrainLoop(
            lora.make_train_step(lm, cfg, opt, rank=LORA_RANK,
                                 alpha=LORA_ALPHA),
            None, ft_src, log_every=eval_every, max_chunk=8, log=silent,
            evaluator=ev, eval_every=eval_every)
        t0 = time.perf_counter()
        _, _, losses = loop.run(tree, st, num_steps=steps)
        dt = time.perf_counter() - t0
        k = max(steps // 10, 1)
        cell = {"initial_loss": round(losses[0], 4),
                "final_loss": round(sum(losses[-k:]) / k, 4),
                "auc_loss": round(sum(losses) / len(losses), 4),
                "eval_curve": [(s, round(v, 4)) for s, v in ev.history],
                "final_eval_loss": round(ev.history[-1][1], 4),
                "steps_per_sec": round(steps / dt, 2),
                "lora_rank": LORA_RANK, "lora_alpha": LORA_ALPHA}
        out["cells"][tag] = cell
        emit(f"curve/{tag}", dt / steps * 1e6,
             f"final={cell['final_loss']} auc={cell['auc_loss']} "
             f"eval={cell['final_eval_loss']}")
    lf32, lgwt = (out["cells"]["adam_lora"]["final_loss"],
                  out["cells"]["gwt2_lora"]["final_loss"])
    out["lora_tracking"] = {"final_loss_ratio": round(lgwt / lf32, 4),
                            "bound": LORA_TRACK_GATE}
    if lgwt > LORA_TRACK_GATE * lf32:
        emit("curve/lora_tracking_ERROR", 0.0,
             f"gwt2_lora final loss {lgwt} > {LORA_TRACK_GATE} * "
             f"adam_lora final {lf32}")
    else:
        emit("curve/lora_tracking_gate", 0.0,
             f"gwt2_lora final {lgwt} vs adam_lora {lf32} "
             f"(ratio {lgwt / lf32:.3f} <= {LORA_TRACK_GATE}, ok)")

    # ---- substrate cells: the non-llama architectures through the same
    # TrainLoop + gwt2 path, no per-arch call-site patches (the encdec
    # frame stub is a pipeline adapter, exactly as in the launcher).
    # Gate: the losses must stay finite — a routing/leaf-plan regression
    # on any substrate shows up as NaN/divergence within a few steps.
    import math as _math
    from repro.data.pipeline import WithEncoderFrames
    from repro.models import encdec as encdec_mod
    sub_steps = 6 if quick else 12
    for tag, arch in [("moe", "qwen2-moe-a2.7b"), ("ssm", "jamba-v0.1-52b"),
                      ("xlstm", "xlstm-350m"),
                      ("encdec", "seamless-m4t-large-v2")]:
        scfg = configs.get_smoke(arch)
        mod = encdec_mod if scfg.arch_class == "encdec" else lm
        src = CorpusLM(corpus, S, 4, seed=0)
        if scfg.arch_class == "encdec":
            src = WithEncoderFrames(src, S // 4, scfg.d_model)
        opt = optim.make("gwt", lr=warmup_cosine(0.01, sub_steps), level=2)
        sparams = mod.init(scfg, jax.random.key(0))
        sst = opt.init(sparams)
        loop = TrainLoop(mod.make_train_step(scfg, opt), None, src,
                         log_every=sub_steps, max_chunk=4, log=silent)
        t0 = time.perf_counter()
        _, _, losses = loop.run(sparams, sst, num_steps=sub_steps)
        dt = time.perf_counter() - t0
        cell = {"arch": scfg.name,
                "initial_loss": round(losses[0], 4),
                "final_loss": round(losses[-1], 4),
                "steps_per_sec": round(sub_steps / dt, 2)}
        out["cells"][f"substrate_{tag}"] = cell
        emit(f"curve/substrate_{tag}", dt / sub_steps * 1e6,
             f"initial={cell['initial_loss']} final={cell['final_loss']}")
        if not all(_math.isfinite(l) for l in losses):
            emit(f"curve/substrate_{tag}_ERROR", 0.0,
                 f"non-finite loss in {losses}")

    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "BENCH_curve_cpu_quick.json" if quick
                        else "BENCH_curve_cpu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    emit("curve/json", 0.0, path)


# ---------------------------------------------------------------------------
# Serving runtime (DESIGN.md §9): continuous batching vs static waves on a
# fixture-corpus-trained tiny llama, open-loop Poisson latency, and int8 KV
# fidelity.  Writes BENCH_serve_cpu.json.  Gates (always): continuous must
# clear SERVE_RATIO_GATE x static tokens/sec on the mixed-length backlog,
# and the int8 KV engine must match the f32 engine's greedy outputs on
# >= SERVE_INT8_MATCH_GATE of generated tokens.  Like the shard bench,
# absolute steps/sec regression vs the committed JSON is NOT gated — on a
# shared 1-core CPU box run-to-run wall-clock variance exceeds any sane
# band; the scheduling RATIO divides that noise out, which is exactly why
# it is the headline.
# ---------------------------------------------------------------------------

SERVE_RATIO_GATE = 1.3
SERVE_INT8_MATCH_GATE = 0.95


def _serve_workload(prompts, n, max_gen, rate, seed):
    """Requests over real corpus prompt windows with the bimodal
    short/long generation mix of ``launch.serve.build_workload``."""
    import numpy as np
    from repro.serve.engine import Request
    rng = np.random.RandomState(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        row = prompts[i % len(prompts)]
        plen = int(rng.randint(max(1, len(row) // 4), len(row) + 1))
        if rng.rand() < 0.25:
            glen = int(rng.randint(max(2, 3 * max_gen // 4), max_gen + 1))
        else:
            glen = int(rng.randint(max(1, max_gen // 16),
                                   max(2, max_gen // 8) + 1))
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        reqs.append(Request(rid=i, prompt=row[:plen].tolist(), max_gen=glen,
                            arrival=t if rate > 0 else 0.0))
    return reqs


def serve_bench(quick: bool):
    import json
    import os
    import tempfile

    import numpy as np

    from repro import configs, optim
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import CorpusLM
    from repro.models import lm
    from repro.optim.schedules import warmup_cosine
    from repro.runtime.fault_tolerance import TrainLoop
    from repro.serve.engine import Engine, EngineConfig

    # -- train the serving model on the fixture corpus and checkpoint it --
    corpus = _fixture_corpus()
    steps = 30 if quick else 72
    S, B = 64, 8
    cfg = configs.LLAMA["llama-60m"].with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512)
    opt = optim.make("gwt", lr=warmup_cosine(0.01, steps), level=2)
    params = lm.init(cfg, jax.random.key(0))
    train_src = CorpusLM(corpus, S, B, seed=0)
    loop = TrainLoop(lm.make_train_step(cfg, opt), None, train_src,
                     log_every=steps, max_chunk=8, log=lambda s: None)
    params, ostate, losses = loop.run(params, opt.init(params),
                                      num_steps=steps)
    ckpt = tempfile.mkdtemp(prefix="repro_serve_ckpt_")
    CheckpointManager(ckpt).save(steps, {"opt": ostate, "params": params},
                                 blocking=True)
    emit("serve/train", 0.0,
         f"{steps} steps, loss {losses[0]:.2f}->{losses[-1]:.2f}")

    # prefill_chunk=32 keeps multi-chunk prefill on the hot path (prompts
    # run 12-48 tokens) while amortizing per-dispatch overhead; gen up to
    # 48 keeps the workload decode-dominated, which is where continuous
    # slot reuse pays.
    max_prompt, max_gen = 48, 48
    n_req = 32 if quick else 96
    ecfg = EngineConfig(num_slots=8, page_size=16,
                        max_ctx=max_prompt + max_gen, prefill_chunk=32)
    eng = Engine.from_checkpoint(cfg, ckpt, ecfg)
    prompts = np.asarray(CorpusLM(corpus, max_prompt, 16,
                                  seed=1).batch(0)["tokens"])
    eng.warmup()
    out = {"config": {"arch": cfg.name, "train_steps": steps,
                      "num_slots": ecfg.num_slots,
                      "page_size": ecfg.page_size,
                      "prefill_chunk": ecfg.prefill_chunk,
                      "max_ctx": ecfg.max_ctx, "requests": n_req,
                      "workload": "bimodal gen 3-6 (75%) / 36-48 (25%), "
                                  "corpus prompts 12-48"},
           "cells": {}}

    # -- headline: backlogged continuous vs static waves (best of 3: the
    # ratio is scheduling, the repeats squeeze out host-noise outliers) --
    keep = ("tokens_per_sec", "requests_per_sec", "makespan_s",
            "generated_tokens")
    for mode, static in (("continuous", False), ("static", True)):
        best = None
        for rep in range(3):
            reqs = _serve_workload(prompts, n_req, max_gen, 0.0, seed=7)
            eng.reset()
            s = eng.run(reqs, static=static)
            if best is None or s["tokens_per_sec"] > best["tokens_per_sec"]:
                best = s
        out["cells"][mode] = {k: round(best[k], 3) for k in keep}
        emit(f"serve/{mode}", 0.0,
             f"{best['tokens_per_sec']:.0f}tok/s "
             f"{best['requests_per_sec']:.1f}req/s "
             f"makespan={best['makespan_s']:.2f}s")
    ratio = (out["cells"]["continuous"]["tokens_per_sec"]
             / out["cells"]["static"]["tokens_per_sec"])
    out["headline"] = {"continuous_over_static": round(ratio, 3),
                       "gate": SERVE_RATIO_GATE}
    if ratio < SERVE_RATIO_GATE:
        emit("serve/ratio_gate_ERROR", 0.0,
             f"continuous only {ratio:.2f}x static tokens/sec "
             f"(gate >= {SERVE_RATIO_GATE}x)")
    else:
        emit("serve/ratio_gate", 0.0,
             f"continuous {ratio:.2f}x static tokens/sec "
             f"(gate >= {SERVE_RATIO_GATE}x)")

    # -- open-loop Poisson arrivals at ~60% of measured capacity:
    # completion latency under load (telemetry — latency percentiles on a
    # 1-core shared box are reported, not gated) --
    rate = 0.6 * out["cells"]["continuous"]["requests_per_sec"]
    reqs = _serve_workload(prompts, max(16, n_req // 2), max_gen, rate,
                           seed=11)
    eng.reset()
    s = eng.run(reqs)
    out["open_loop"] = {"arrival_rps": round(rate, 2),
                        "requests": len(reqs),
                        "p50_s": round(s["p50_s"], 4),
                        "p99_s": round(s["p99_s"], 4),
                        "tokens_per_sec": round(s["tokens_per_sec"], 1)}
    emit("serve/open_loop", 0.0,
         f"poisson {rate:.1f}req/s p50={s['p50_s']*1e3:.0f}ms "
         f"p99={s['p99_s']*1e3:.0f}ms")

    # -- int8 KV fidelity: same checkpoint, quantized pages --------------
    eng8 = Engine.from_checkpoint(cfg, ckpt, EngineConfig(
        num_slots=ecfg.num_slots, page_size=ecfg.page_size,
        max_ctx=ecfg.max_ctx, prefill_chunk=ecfg.prefill_chunk,
        kv_quant="int8"))
    eng8.warmup()
    n8 = 16 if quick else 32
    outs = {}
    for tag, e in (("f32", eng), ("int8", eng8)):
        reqs = _serve_workload(prompts, n8, max_gen, 0.0, seed=13)
        e.reset()
        e.run(reqs)
        outs[tag] = [r.generated for r in reqs]
    total = match = 0
    for a, b in zip(outs["f32"], outs["int8"]):
        total += len(a)
        match += sum(int(x == y) for x, y in zip(a, b))
    rate8 = match / total
    out["int8_kv"] = {"match_rate": round(rate8, 4), "tokens": total,
                      "gate": SERVE_INT8_MATCH_GATE,
                      "arena_bytes_f32": eng.kv_bytes(),
                      "arena_bytes_int8": eng8.kv_bytes()}
    shrink = eng8.kv_bytes() / eng.kv_bytes()
    if rate8 < SERVE_INT8_MATCH_GATE:
        emit("serve/int8_gate_ERROR", 0.0,
             f"int8 KV greedy match {rate8:.3f} < {SERVE_INT8_MATCH_GATE} "
             f"({match}/{total})")
    else:
        emit("serve/int8_gate", 0.0,
             f"int8 KV matches f32 greedy on {rate8:.1%} of {total} tokens "
             f"(arena {shrink:.2f}x f32 bytes)")

    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "BENCH_serve_cpu_quick.json" if quick
                        else "BENCH_serve_cpu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    emit("serve/json", 0.0, path)


# ---------------------------------------------------------------------------
# Observability overhead + artifact validity (DESIGN.md §12).  Two gates:
#
#   1. steps/sec with taps fused into the superstep (and telemetry
#      writing JSONL) must stay >= OBS_OVERHEAD_GATE of the taps-off
#      loop — the taps ride the existing norm pass and log_every fetch,
#      so the budget is tight (<=2%).  Estimator: adjacent off/on
#      segment PAIRS, gate on the median of per-pair ratios — on a
#      shared 1-core box single-segment wall clock swings +-10%, far
#      wider than the band, and only pairing + a median divides that
#      host noise out (same reasoning as the serve scheduling-ratio
#      gate).  Up to 3 rounds, passing if any round's median clears.
#   2. the emitted artifacts are real: every metrics.jsonl line parses,
#      train_step records carry tap scalars, serve_request records carry
#      latency fields, and trace.json passes the Chrome trace_event
#      schema check with spans from BOTH the train loop and the serve
#      engine.
# ---------------------------------------------------------------------------

OBS_OVERHEAD_GATE = 0.98


def obs_bench(quick: bool):
    import json
    import os
    import tempfile

    from repro import configs, obs, optim
    from repro.data.pipeline import SyntheticLM
    from repro.launch.serve import build_workload
    from repro.models import lm
    from repro.obs import trace as obs_trace
    from repro.runtime.fault_tolerance import TrainLoop
    from repro.serve.engine import Engine, EngineConfig

    cfg = configs.get_smoke("llama-60m")
    B, S = 1, 64
    chunk = 20                      # superstep length = log cadence,
    seg = 4 * chunk                 # matching step_bench's chunk
    pairs = 3 if quick else 5       # off/on segment pairs per round
    silent = lambda s: None  # noqa: E731

    opt = optim.make("gwt", lr=1e-3, level=2)
    params = lm.init(cfg, jax.random.key(0))
    st = opt.init(params)
    data = SyntheticLM(cfg.vocab, S, B, seed=0)
    loop_off = TrainLoop(lm.make_train_step(cfg, opt), None, data,
                         log_every=chunk, max_chunk=chunk, log=silent)
    loop_on = TrainLoop(lm.make_train_step(cfg, opt), None, data,
                        log_every=chunk, max_chunk=chunk, log=silent,
                        tap_step=lm.make_train_step(cfg, opt, taps=True))

    # warm both superstep jits before timing anything
    obs.configure()                 # null telemetry
    for lp in (loop_off, loop_on):
        lp.run(*jax.tree.map(lambda a: a.copy(), (params, st)),
               num_steps=chunk)

    # -- paired segments: taps-off under the null telemetry (the
    # metrics-dir-unset path), taps-on with the JSONL sink + tracer live
    # so each pair covers the full observability cost back-to-back --
    import statistics
    meas = tempfile.mkdtemp(prefix="repro_obs_meas_")
    round_medians = []
    off = on = ratio = 0.0
    for _ in range(3):
        offs, ons = [], []
        for _ in range(pairs):
            obs.configure()
            offs.append(_loop_steps_per_sec(loop_off, params, st, seg,
                                            repeats=1))
            obs.configure(meas, run={"cmd": "bench-obs"})
            ons.append(_loop_steps_per_sec(loop_on, params, st, seg,
                                           repeats=1))
        med = statistics.median(n / o for n, o in zip(ons, offs))
        round_medians.append(round(med, 4))
        if med > ratio:
            ratio = med
            off = statistics.median(offs)
            on = statistics.median(ons)
        if ratio >= OBS_OVERHEAD_GATE:
            break
    obs.shutdown()
    out = {"config": {"arch": cfg.name, "batch": B, "seq": S,
                      "chunk": chunk, "segment_steps": seg,
                      "pairs_per_round": pairs},
           "cells": {"taps_off_steps_per_sec": round(off, 2),
                     "taps_on_steps_per_sec": round(on, 2),
                     "on_over_off": round(ratio, 4),
                     "round_medians": round_medians,
                     "gate": OBS_OVERHEAD_GATE}}
    if ratio < OBS_OVERHEAD_GATE:
        emit("obs/overhead_gate_ERROR", 0.0,
             f"taps-on {on:.1f} steps/s is {ratio:.3f}x taps-off "
             f"{off:.1f} (gate >= {OBS_OVERHEAD_GATE}x)")
    else:
        emit("obs/overhead_gate", 0.0,
             f"taps-on {on:.1f} steps/s = {ratio:.3f}x taps-off "
             f"{off:.1f} (gate >= {OBS_OVERHEAD_GATE}x)")

    # -- artifact phase: one fresh telemetry session covering a train
    # chunk AND a small serve run, then validate what it wrote --
    art = tempfile.mkdtemp(prefix="repro_obs_art_")
    tel = obs.configure(art, run={"cmd": "bench-obs", "arch": cfg.name})
    loop_on.run(*jax.tree.map(lambda a: a.copy(), (params, st)),
                num_steps=chunk)
    eng = Engine(cfg, params, EngineConfig(
        num_slots=2, page_size=8, max_ctx=24, prefill_chunk=16))
    eng.warmup()
    reqs = build_workload(4, cfg.vocab, 16, 8, 0.0, seed=3)
    eng.run(reqs)
    assert tel is obs.get()
    obs.shutdown()                  # writes <art>/trace.json

    with open(os.path.join(art, "metrics.jsonl")) as f:
        records = [json.loads(line) for line in f]
    kinds = {}
    for r in records:
        kinds.setdefault(r.get("kind"), []).append(r)
    train_recs = kinds.get("train_step", [])
    tapped = [r for r in train_recs
              if any("/" in k for k in r if k not in ("kind",))]
    serve_recs = kinds.get("serve_request", [])
    probs = []
    if not records or records[0].get("kind") != "run" \
            or "run" not in records[0]:
        probs.append("missing run-provenance header")
    if not tapped:
        probs.append("no train_step records with tap scalars")
    if len(serve_recs) != len(reqs):
        probs.append(f"{len(serve_recs)} serve_request records for "
                     f"{len(reqs)} requests")
    if any("ttft_s" not in r or "latency_s" not in r for r in serve_recs):
        probs.append("serve_request records missing latency fields")

    with open(os.path.join(art, "trace.json")) as f:
        doc = json.load(f)
    try:
        obs_trace.validate(doc)
    except Exception as e:  # noqa: BLE001 - surfaced as a gate row
        probs.append(f"trace schema: {type(e).__name__}: {e}")
    evs = doc.get("traceEvents", [])
    cats = {e.get("cat") for e in evs}
    names = {e.get("name") for e in evs}
    if not {"prefetch", "dispatch", "block"} <= names:
        probs.append(f"train spans missing from trace (names={names})")
    if "serve" not in cats:
        probs.append("no serve-category events in trace")

    out["artifacts"] = {
        "metrics_records": len(records),
        "train_step_records": len(train_recs),
        "tap_keys": sorted(k for k in (tapped[0] if tapped else {})
                           if "/" in k)[:8],
        "serve_request_records": len(serve_recs),
        "trace_events": len(evs),
        "trace_cats": sorted(c for c in cats if c)}
    if probs:
        emit("obs/artifact_ERROR", 0.0, "; ".join(probs))
    else:
        emit("obs/artifact", 0.0,
             f"{len(records)} jsonl records ({len(train_recs)} train_step, "
             f"{len(tapped)} tapped, {len(serve_recs)} serve_request), "
             f"{len(evs)} trace events across cats "
             f"{sorted(c for c in cats if c)}")

    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "BENCH_obs_cpu_quick.json" if quick
                        else "BENCH_obs_cpu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    emit("obs/json", 0.0, path)


TABLES = {
    "table1": table1_memory,
    "table2": table2_pretrain,
    "table3": table3_throughput,
    "table4": table4_seqlen,
    "table11": table11_memory_estimate,
    "table12": table12_levels,
    "kernels": kernels_bench,
    "trace": trace_bench,
    "step": step_bench,
    "state": state_bench,
    "shard": shard_bench,
    "data": data_bench,
    "curve": curve_bench,
    "serve": serve_bench,
    "obs": obs_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--shard-worker", action="store_true",
                    help=argparse.SUPPRESS)  # internal: 8-device subprocess
    args = ap.parse_args()
    if args.shard_worker:
        _shard_worker(args.quick)
        return
    if args.only and args.only not in TABLES:
        # a typo'd --only would otherwise run nothing and exit 0 — a CI
        # gate that silently stops gating.
        ap.error(f"unknown bench {args.only!r}; choose from "
                 f"{', '.join(TABLES)}")
    print("name,us_per_call,derived")
    for name, fn in TABLES.items():
        if args.only and args.only != name:
            continue
        try:
            fn(args.quick)
        except Exception as e:  # keep the harness robust
            emit(f"{name}/ERROR", 0.0, f"{type(e).__name__}: {e}")
    bad = [r for r in ROWS if "ERROR" in r[0]]
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
