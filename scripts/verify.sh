#!/usr/bin/env bash
# Single CI entry point: compat smoke-import check + benchmark gates +
# the tier-1 suite.
#
#   ./scripts/verify.sh            # full tier-1
#   ./scripts/verify.sh --smoke    # import check only (seconds)
#   ./scripts/verify.sh --quick    # import check + benchmark gates only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compat smoke: import every repro module under the installed JAX =="
python - <<'PY'
import importlib, pathlib, sys
src = pathlib.Path("src")
mods = sorted(".".join(p.relative_to(src).with_suffix("").parts)
              for p in src.rglob("*.py") if p.name != "__init__.py")
failed = []
for m in mods:
    try:
        importlib.import_module(m)
    except Exception as e:  # noqa: BLE001 - report everything
        failed.append((m, f"{type(e).__name__}: {e}"))
for m, err in failed:
    print(f"FAIL {m}: {err}")
print(f"{len(mods) - len(failed)}/{len(mods)} modules import cleanly")
sys.exit(1 if failed else 0)
PY

if [[ "${1:-}" == "--smoke" ]]; then
    exit 0
fi

echo "== trace/compile benchmark smoke (bucketed engine vs per-leaf) =="
python -m benchmarks.run --only trace --quick

echo "== train-step runtime benchmark (pipelined loop + donation gate; =="
echo "== fails on >20% steps/sec regression vs committed BENCH_step_cpu, =="
echo "== if gwt+int8 opt state is <10x under full-Adam f32, or if the =="
echo "== fused-write one-launch peak live bytes >= the staged pipeline) =="
python -m benchmarks.run --only step --quick

echo "== optimizer-state substrate accounting (family x codec matrix; =="
echo "== fails unless int8 shrinks every moment-bearing family) =="
python -m benchmarks.run --only state --quick

echo "== sharded train path benchmark (8-device sim; fails unless the =="
echo "== compressed DP wire moves >=2x fewer bytes at level >= 2) =="
python -m benchmarks.run --only shard --quick

echo "== data subsystem: corpus-build CLI smoke + loader throughput =="
echo "== (fails if process workers are slower than the prefetch thread =="
echo "== on the tokenization-heavy source) =="
python -m benchmarks.run --only data --quick

echo "== loss-curve harness: gwt/gwt+int8/adam/galore pre-training, =="
echo "== gwt2-LoRA vs adam-LoRA fine-tuning, and the moe/ssm/xlstm/ =="
echo "== encdec substrate smokes, all on the fixture corpus (fails if =="
echo "== any optimizer stops learning, the quantized or LoRA gwt cells =="
echo "== stop tracking their f32/adam references, or any substrate =="
echo "== goes non-finite) =="
python -m benchmarks.run --only curve --quick

echo "== serving runtime: continuous batching vs static waves on the =="
echo "== fixture-corpus model (fails unless continuous >= 1.3x static =="
echo "== tokens/sec on the mixed-length workload, or if int8 KV greedy =="
echo "== agreement with f32 drops below 95%) =="
python -m benchmarks.run --only serve --quick

echo "== observability: on-device taps + telemetry overhead (fails if =="
echo "== the tapped loop drops below 98% of the taps-off steps/sec, or =="
echo "== if the emitted JSONL/Chrome-trace artifacts are malformed) =="
python -m benchmarks.run --only obs --quick

if [[ "${1:-}" == "--quick" ]]; then
    exit 0
fi

if [[ "${REPRO_FULL_MATRIX:-0}" == "1" ]]; then
    echo "== full scenario matrix (nightly tier: substrate x family x =="
    echo "== codec cross-product + launcher SIGTERM sweep, --runslow) =="
    python -m pytest tests/test_scenario_matrix.py -q --runslow
fi

echo "== tier-1 test suite =="
# Wall-clock budget: tier-1 must stay in its current envelope (~15 min on
# the 1-core CI box).  When it drifts, run with --durations=15 to find the
# hot tests; the scenario matrix keeps only a 6-cell representative subset
# in tier-1 — everything else belongs behind the slow marker.
python -m pytest -x -q
